"""Admission control (core/admission.py) + its scheduler integration:
weighted round-robin stops a flooding tenant from starving anyone
(regression for the PR-5 FIFO drain), token buckets reject floods at
submit time with a retry hint, priority classes are strict, fair-share
shedding keeps light tenants admitted under global pressure, and a
wedged-daemon close() resolves every still-queued future to an error
envelope instead of stranding its caller."""
import threading
import time

import pytest

from repro.core import (AdmissionController, AdmissionError, AdmissionPolicy,
                        MemoryScheduler, MemoryService, Message,
                        PRIORITY_HIGH, PRIORITY_LOW, RetrieveRequest,
                        TenantPolicy)
from repro.core.admission import tenant_of
from repro.core.api import CompactRequest, RecordRequest
from repro.core.embedder import HashEmbedder

EMB = HashEmbedder()


def _svc(**kw):
    kw.setdefault("use_kernel", False)
    kw.setdefault("budget", 800)
    return MemoryService(EMB, **kw)


def _fill(svc, tenants=("a", "b")):
    for t in tenants:
        svc.record(f"{t}/c0", "s0",
                   [Message("U", f"I live in City-{t}.", 1.0),
                    Message("U", "I work as a welder.", 2.0)])
    return svc


class FakeClock:
    def __init__(self, t=1000.0):
        self.t = t

    def __call__(self):
        return self.t


# -- policy validation ---------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError, match="weight"):
        TenantPolicy(weight=0)
    with pytest.raises(ValueError, match="rate"):
        TenantPolicy(rate=-1)
    with pytest.raises(ValueError, match="burst"):
        TenantPolicy(burst=0)
    with pytest.raises(ValueError, match="max_queued_global"):
        AdmissionPolicy(max_queued_global=0)


def test_tenant_of_is_namespace_prefix():
    assert tenant_of(RetrieveRequest("acme/conv7", "q")) == "acme"
    assert tenant_of(RetrieveRequest("solo", "q")) == "solo"
    assert tenant_of(CompactRequest()) == "__system__"


# -- rate limiting (deterministic via injected clock) --------------------------

def test_rate_limit_rejects_flood_and_refills():
    clock = FakeClock()
    ctl = AdmissionController(AdmissionPolicy(
        default=TenantPolicy(rate=10.0, burst=2)), clock=clock)
    ctl.admit_batch([("a", 2)])                       # burst drained
    with pytest.raises(AdmissionError) as ei:
        ctl.admit_batch([("a", 1)])
    assert ei.value.reason == "rate_limited"
    assert ei.value.tenant == "a"
    assert ei.value.retry_after_s == pytest.approx(0.1)
    clock.t += 0.1                                    # one token refilled
    ctl.admit_batch([("a", 1)])
    assert ctl.counters["admitted"] == 3
    assert ctl.counters["rate_limited"] == 1


def test_admit_batch_is_all_or_nothing():
    clock = FakeClock()
    ctl = AdmissionController(AdmissionPolicy(
        tenants={"limited": TenantPolicy(rate=1.0, burst=1)}), clock=clock)
    # the block touches an unlimited tenant AND an over-limit one: the
    # rejection must consume nothing from anyone
    ctl.admit_batch([("limited", 1)])
    with pytest.raises(AdmissionError):
        ctl.admit_batch([("free", 3), ("limited", 1)])
    assert ctl.counters["admitted"] == 1              # only the first call


def test_tenant_queue_cap_sheds():
    ctl = AdmissionController(AdmissionPolicy(
        default=TenantPolicy(max_queued=2), shed_retry_after_s=0.25))
    for i in range(2):
        ctl.admit_batch([("a", 1)])
        ctl.push("a", i)
    with pytest.raises(AdmissionError) as ei:
        ctl.admit_batch([("a", 1)])
    assert ei.value.reason == "tenant_queue_full"
    assert ei.value.retry_after_s == 0.25


def test_global_cap_sheds_only_above_fair_share():
    ctl = AdmissionController(AdmissionPolicy(max_queued_global=8))
    for i in range(8):                                # flood fills the cap
        ctl.admit_batch([("flood", 1)])
        ctl.push("flood", i)
    with pytest.raises(AdmissionError) as ei:
        ctl.admit_batch([("flood", 1)])
    assert ei.value.reason == "overloaded"
    # a light tenant is below its fair share: still admitted (soft
    # overflow), the flood cannot close the door on it
    ctl.admit_batch([("light", 1)])
    ctl.push("light", "x")
    assert ctl.stats()["tenants"]["light"]["queued"] == 1


# -- selection: WRR + priority -------------------------------------------------

def test_select_splits_slots_by_weight():
    ctl = AdmissionController(AdmissionPolicy(
        tenants={"big": TenantPolicy(weight=3.0)}))
    for i in range(40):
        ctl.push("big", ("big", i))
        ctl.push("small", ("small", i))
    got = ctl.select(16)
    by = {"big": 0, "small": 0}
    for t, _ in got:
        by[t] += 1
    assert by["big"] == 12 and by["small"] == 4
    # FIFO within each tenant
    assert [i for t, i in got if t == "big"] == list(range(12))


def test_select_priority_is_strict():
    ctl = AdmissionController(AdmissionPolicy(tenants={
        "hi": TenantPolicy(priority=PRIORITY_HIGH),
        "lo": TenantPolicy(priority=PRIORITY_LOW)}))
    for i in range(6):
        ctl.push("lo", ("lo", i))                     # low queued FIRST
    for i in range(4):
        ctl.push("hi", ("hi", i))
    got = ctl.select(6)
    assert [t for t, _ in got] == ["hi"] * 4 + ["lo"] * 2


def test_select_caps_flood_at_fair_share_but_not_solo_tenants():
    clock = FakeClock()
    ctl = AdmissionController(AdmissionPolicy(share_window_s=0.1),
                              clock=clock)
    for i in range(100):
        ctl.push("flood", ("flood", i))
    for i in range(2):
        ctl.push("light", ("light", i))
    got = ctl.select(16)
    by = {"flood": 0, "light": 0}
    for t, _ in got:
        by[t] += 1
    # flood is capped at its entry-time share (16/2 tenants = 8) even
    # though light used only 2 of its 8 — the spare slots would otherwise
    # grow the tick's batch (and its execution time) for everyone
    assert by == {"flood": 8, "light": 2}
    # drained-but-recent tenants keep their reservation for the share
    # window (closed-loop clients are queue-empty exactly while their
    # tick executes): flood is still capped at 8 of 16
    assert len(ctl.select(16)) == 8
    clock.t += 1.0
    # ... and once the window passes, flood queues genuinely alone and
    # gets full ticks: the cap never costs a single-tenant deployment
    assert len(ctl.select(16)) == 16


def test_select_fractional_weights_make_progress():
    ctl = AdmissionController(AdmissionPolicy(
        default=TenantPolicy(weight=0.25)))
    for i in range(4):
        ctl.push("a", i)
    assert len(ctl.select(4)) == 4


# -- scheduler integration -----------------------------------------------------

def test_flooding_tenant_cannot_starve_another():
    """The PR-5 regression: under FIFO, 100 queued requests from tenant A
    pushed tenant B's single request 13 ticks out (max_batch=8).  With WRR
    B's request rides the FIRST tick."""
    svc = _fill(_svc())
    sched = MemoryScheduler(svc, start=False)
    sched.max_batch = 8
    flood = sched.submit_many(
        [RetrieveRequest("a/c0", "Which city?") for _ in range(100)])
    single = sched.submit(RetrieveRequest("b/c0", "Which city?"))
    sched.run_tick_once()
    assert single.done(), "WRR must grant the light tenant a slot in the " \
                          "first tick despite 100 queued ahead of it"
    # A is capped at its fair share of the tick (8/2 tenants = 4): it can
    # not absorb the slots B left unused and inflate the tick
    assert sum(f.done() for f in flood) == 4
    while sched.admission.total_queued:
        sched.run_tick_once()
    assert all(f.result().ok for f in flood)
    assert single.result().ok
    sched.close()


def test_scheduler_rate_limit_surfaces_as_admission_error():
    svc = _fill(_svc())
    sched = MemoryScheduler(svc, start=False, admission=AdmissionPolicy(
        tenants={"a": TenantPolicy(rate=0.001, burst=2)}))
    sched.submit_many([RetrieveRequest("a/c0", "q")] * 2)
    with pytest.raises(AdmissionError):
        sched.submit(RetrieveRequest("a/c0", "q"))
    # the other tenant is untouched by a's limit
    fut = sched.submit(RetrieveRequest("b/c0", "q"))
    while sched.admission.total_queued:
        sched.run_tick_once()
    assert fut.result().ok
    sched.close()


def test_default_policy_admits_everything_fifo():
    """No limits configured -> every request admitted, and read-your-writes
    across tenants still holds because execution re-sorts to submission
    order."""
    svc = _fill(_svc())
    sched = MemoryScheduler(svc, start=False)
    futs = sched.submit_many(
        [RetrieveRequest("a/c0", "q"), RetrieveRequest("b/c0", "q")] * 10)
    sched.run_tick_once()
    assert all(f.done() and f.result().ok for f in futs)
    st = sched.stats()
    assert st["admission"]["admitted"] == 20
    assert st["admission"]["shed"] == 0
    sched.close()


def test_stats_exposes_per_tenant_accounting():
    svc = _fill(_svc())
    sched = MemoryScheduler(svc, start=False, admission=AdmissionPolicy(
        tenants={"a": TenantPolicy(weight=2.0, max_queued=1)}))
    sched.submit(RetrieveRequest("a/c0", "q"))
    with pytest.raises(AdmissionError):
        sched.submit(RetrieveRequest("a/c0", "q"))
    adm = sched.stats()["admission"]
    assert adm["tenants"]["a"]["queued"] == 1
    assert adm["tenants"]["a"]["shed"] == 1
    assert adm["tenants"]["a"]["weight"] == 2.0
    sched.run_tick_once()
    sched.close()


# -- wedged-daemon close (satellite: no stranded futures) ----------------------

class _WedgingService:
    """A service whose execute() blocks until released — the stuck-device
    stand-in for close()'s wedged-daemon path."""

    def __init__(self):
        self.release = threading.Event()
        self.entered = threading.Event()
        self.scheduler = None
        self.runtime = None

    def execute(self, requests):
        self.entered.set()
        self.release.wait(timeout=30)
        return [f"payload-{r.query}" for r in requests]


def test_close_resolves_stranded_futures_when_daemon_wedged():
    svc = _WedgingService()
    sched = MemoryScheduler(svc, tick_interval_s=0.001, max_batch=1)
    wedged = sched.submit(RetrieveRequest("a/c0", "in-flight"))
    assert svc.entered.wait(timeout=5)                # tick is now stuck
    stranded = [sched.submit(RetrieveRequest("a/c0", f"queued-{i}"))
                for i in range(3)]
    t0 = time.monotonic()
    sched.close(timeout=0.2)
    assert time.monotonic() - t0 < 5
    for f in stranded:
        resp = f.result(timeout=1)                    # must NOT hang
        assert resp.status == "error"
        assert "wedged" in resp.error
        assert resp.op == "retrieve"
    assert not wedged.done()                          # stayed with its tick
    svc.release.set()                                 # daemon recovers
    assert wedged.result(timeout=5).ok                # resolves normally
    # a recovered daemon's late set_result on error-resolved futures is
    # swallowed — close() already gave those callers their answer
    for f in stranded:
        assert f.result().status == "error"


def test_close_runs_queue_when_daemon_healthy():
    svc = _fill(_svc())
    sched = MemoryScheduler(svc, start=False)
    futs = sched.submit_many([RetrieveRequest("a/c0", "q")] * 5)
    sched.close()                                     # drains, no daemon
    assert all(f.result().ok for f in futs)


# -- counter consistency under concurrency (satellite: stats race) -------------

def test_stats_snapshot_consistent_under_concurrent_ticks():
    svc = _fill(_svc())
    sched = MemoryScheduler(svc, tick_interval_s=0.0005, max_batch=8)
    stop = threading.Event()
    torn = []

    def reader():
        while not stop.is_set():
            st = sched.stats()
            # requests is bumped in the same locked block as ticks: a
            # snapshot can never show requests without its tick
            if st["requests"] < st["max_tick_batch"]:
                torn.append(st)

    t = threading.Thread(target=reader)
    t.start()
    for _ in range(30):
        fs = sched.submit_many([RetrieveRequest("a/c0", "q")] * 4)
        for f in fs:
            f.result(timeout=10)
    stop.set()
    t.join()
    sched.close()
    assert not torn


def test_record_requests_share_tenant_accounting():
    svc = _svc()
    sched = MemoryScheduler(svc, start=False, admission=AdmissionPolicy(
        tenants={"a": TenantPolicy(max_queued=1)}))
    sched.submit(RecordRequest("a/c0", "s0",
                               (Message("U", "hello", 1.0),)))
    with pytest.raises(AdmissionError) as ei:
        sched.submit(RecordRequest("a/c1", "s1",
                                   (Message("U", "hi", 2.0),)))
    assert ei.value.reason == "tenant_queue_full"
    sched.run_tick_once()
    sched.close()
