"""Serving engine: slot-based continuous batching over jit'd prefill/decode.

A fixed number of batch slots share one decode computation; each slot has its
own cache region and position (vector cache_pos).  Admission prefills a
single request (B=1), converts its prefill cache to the decode layout, and
inserts it into the batched caches at the slot's batch index — the standard
continuous-batching dataflow, expressed with dynamic_update_slice_in_dim over
the cache pytree (batch axis located via the cache shape specs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS_ID, HashTokenizer, default_tokenizer
from repro.models import transformer
from repro.models.model_api import Model
from repro.serving.requests import Request, Response
from repro.serving.sampler import SamplerConfig, sample


def _batch_axis(axes) -> int:
    return axes.index("batch")


class Engine:
    def __init__(self, model: Model, params, *, max_len: int = 512,
                 slots: int = 4, sampler: SamplerConfig = SamplerConfig(),
                 window_override: Optional[int] = None,
                 tokenizer: Optional[HashTokenizer] = None, seed: int = 0):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_len = max_len
        self.slots = slots
        self.sampler = sampler
        self.window_override = window_override
        self.tokenizer = tokenizer or default_tokenizer()
        self.key = jax.random.PRNGKey(seed)

        self.caches = model.init_caches(slots, max_len,
                                        window_override=window_override)
        self._cache_specs = transformer.decoder_cache_shape_specs(
            self.cfg, slots, max_len, self.cfg.cdtype,
            cross=self.cfg.is_encoder_decoder,
            enc_len=self.cfg.encoder_seq_len,
            window_override=window_override)
        self.slot_pos = np.zeros((slots,), np.int32)
        self.slot_active = np.zeros((slots,), bool)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_out: List[List[int]] = [[] for _ in range(slots)]
        self.slot_tokens = np.zeros((slots,), np.int32)
        self.stats = {"decode_steps": 0, "tokens_out": 0, "admitted": 0}

        self._decode = jax.jit(
            lambda p, t, c, pos: model.decode_step(
                p, t, c, pos, window_override=window_override))
        self._prefill = jax.jit(model.prefill)

    # -- admission -----------------------------------------------------------
    def _insert_cache(self, slot: int, single_caches):
        def ins(full, single, spec):
            ax = _batch_axis(spec[1])
            return jax.lax.dynamic_update_slice_in_dim(
                full, single.astype(full.dtype), slot, axis=ax)
        self.caches = jax.tree.map(
            ins, self.caches, single_caches, self._cache_specs,
            is_leaf=lambda x: x is None)

    def admit(self, req: Request) -> int:
        free = np.where(~self.slot_active)[0]
        assert free.size, "no free slot"
        slot = int(free[0])
        toks = req.prompt_tokens[: self.max_len - req.max_new_tokens - 1]
        batch = {"tokens": jnp.asarray(toks, jnp.int32)[None]}
        logits, pre_caches = self._prefill(self.params, batch)
        single = self.model.prepare_decode_caches(
            pre_caches, len(toks), self.max_len,
            window_override=self.window_override)
        self._insert_cache(slot, single)
        self.key, sk = jax.random.split(self.key)
        first = int(sample(logits, sk, self.sampler)[0])
        self.slot_pos[slot] = len(toks)
        self.slot_active[slot] = True
        self.slot_req[slot] = req
        self.slot_out[slot] = [first]
        self.slot_tokens[slot] = first
        self.stats["admitted"] += 1
        return slot

    @property
    def has_free_slot(self) -> bool:
        return bool((~self.slot_active).any())

    # -- decode ----------------------------------------------------------------
    def step(self) -> List[Response]:
        """One batched decode step across all slots; returns finished
        responses."""
        if not self.slot_active.any():
            return []
        tokens = jnp.asarray(self.slot_tokens)[:, None]
        pos = jnp.asarray(self.slot_pos)
        logits, self.caches = self._decode(self.params, tokens, self.caches, pos)
        self.key, sk = jax.random.split(self.key)
        nxt = np.asarray(sample(logits, sk, self.sampler))
        self.stats["decode_steps"] += 1

        done: List[Response] = []
        for s in range(self.slots):
            if not self.slot_active[s]:
                continue
            self.slot_pos[s] += 1
            tok = int(nxt[s])
            self.slot_out[s].append(tok)
            self.slot_tokens[s] = tok
            self.stats["tokens_out"] += 1
            req = self.slot_req[s]
            eos = req.eos_id if req.eos_id is not None else EOS_ID
            if (len(self.slot_out[s]) >= req.max_new_tokens
                    or tok == eos
                    or self.slot_pos[s] >= self.max_len - 1):
                done.append(Response(req.request_id, list(self.slot_out[s]),
                                     prompt_len=len(req.prompt_tokens)))
                self.slot_active[s] = False
                self.slot_req[s] = None
                self.slot_out[s] = []
        return done

    # -- convenience -------------------------------------------------------------
    def generate(self, prompts: List[str], max_new_tokens: int = 32) -> List[str]:
        from repro.serving.scheduler import ContinuousBatcher
        reqs = [Request(self.tokenizer.encode(p), max_new_tokens)
                for p in prompts]
        batcher = ContinuousBatcher(self)
        out = batcher.run(reqs)
        return [self.tokenizer.decode(out[r.request_id].tokens) for r in reqs]
