"""HTTP serving surface (serving/frontend.py) end to end: a real
ThreadingHTTPServer over a real service + scheduler, driven through
urllib — record -> retrieve -> stream round trips, api-key tenancy
isolation, the error contract (401 / 400 / 404 / 429 + Retry-After), and
the SDK's HttpMemory client speaking the same wire format."""
import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.core import (AdmissionPolicy, MemoriClient, MemoryScheduler,
                        MemoryService, TenantPolicy)
from repro.core.embedder import HashEmbedder
from repro.core.sdk import AdmissionError, HttpMemory
from repro.serving.frontend import MemoryFrontend

EMB = HashEmbedder()
KEYS = {"key-acme": "acme", "key-beta": "beta"}


@pytest.fixture()
def frontend():
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    sched = MemoryScheduler(svc, tick_interval_s=0.002, max_batch=16)
    fe = MemoryFrontend(svc, KEYS).start()
    yield fe
    fe.close()
    sched.close()


def _call(fe, path, body=None, key="key-acme", method=None):
    req = urllib.request.Request(
        fe.address + path,
        data=None if body is None else json.dumps(body).encode(),
        headers={"Authorization": f"Bearer {key}"},
        method=method or ("GET" if body is None else "POST"))
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            return r.status, json.loads(r.read().decode()), r.headers
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read().decode()), e.headers


def _record_body(city="Lisbon"):
    return {"namespace": "conv0", "session_id": "s0",
            "messages": [{"speaker": "U", "text": f"I live in {city}.",
                          "timestamp": 1.0},
                         {"speaker": "U", "text": "I work as a welder.",
                          "timestamp": 2.0}]}


# -- the acceptance path: record -> retrieve -> stream through real HTTP ------

def test_record_then_retrieve_round_trip(frontend):
    st, env, _ = _call(frontend, "/v1/record", _record_body())
    assert st == 200 and env["status"] == "ok"
    assert env["op"] == "record" and env["payload"]["flushed"]

    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"namespace": "conv0",
                        "query": "Which city does the user live in?"})
    assert st == 200 and env["status"] == "ok"
    pay = env["payload"]
    assert pay["kind"] == "retrieved_context"
    assert any("lisbon" in t["object"] for t in pay["triples"])
    assert pay["token_count"] == env["token_count"] > 0
    assert env["batch_size"] >= 1


def test_streaming_retrieve_ndjson(frontend):
    _call(frontend, "/v1/record", _record_body())
    req = urllib.request.Request(
        frontend.address + "/v1/retrieve",
        data=json.dumps({"namespace": "conv0", "stream": True,
                         "queries": [{"query": "Which city?"},
                                     {"query": "What job?"},
                                     {"query": "Any pets?"}]}).encode(),
        headers={"Authorization": "Bearer key-acme"})
    with urllib.request.urlopen(req, timeout=30) as r:
        assert r.headers["Content-Type"] == "application/x-ndjson"
        events = [json.loads(line) for line in r.read().decode().splitlines()
                  if line.strip()]
    assert events[0] == {"event": "accepted", "count": 3}
    results = [e for e in events if e["event"] == "result"]
    assert sorted(e["index"] for e in results) == [0, 1, 2]
    assert all(e["response"]["status"] == "ok" for e in results)
    assert events[-1]["event"] == "done" and events[-1]["errors"] == 0


def test_batch_retrieve_preserves_submission_order(frontend):
    _call(frontend, "/v1/record", _record_body())
    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"namespace": "conv0",
                        "queries": [{"query": "city", "top_k": 1},
                                    {"query": "job"}]})
    assert st == 200 and len(env["responses"]) == 2
    assert all(r["status"] == "ok" for r in env["responses"])


# -- tenancy ------------------------------------------------------------------

def test_api_keys_isolate_tenants(frontend):
    _call(frontend, "/v1/record", _record_body("Quito"), key="key-acme")
    # beta uses the SAME namespace string but sees nothing of acme's
    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"namespace": "conv0", "query": "Which city?"},
                       key="key-beta")
    assert st == 200
    assert env["payload"]["triples"] == []
    # and beta's evict of "conv0" cannot touch acme's rows
    st, env, _ = _call(frontend, "/v1/evict", {"namespace": "conv0"},
                       key="key-beta")
    assert st == 200 and env["payload"] == 0
    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"namespace": "conv0", "query": "Which city?"},
                       key="key-acme")
    assert any("quito" in t["object"] for t in env["payload"]["triples"])


def test_unknown_key_is_401(frontend):
    st, env, _ = _call(frontend, "/v1/stats", key="nope")
    assert st == 401 and env["status"] == "error"


# -- error contract -----------------------------------------------------------

def test_bad_bodies_are_400(frontend):
    st, env, _ = _call(frontend, "/v1/record", {"namespace": "c"})
    assert st == 400 and "messages" in env["error"]
    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"query": "q", "stages": ["bm42"]})
    assert st == 400 and "unknown retrieval stages" in env["error"]


def test_unknown_route_is_404(frontend):
    st, env, _ = _call(frontend, "/v1/nope", {})
    assert st == 404


def test_rate_limited_tenant_gets_429_with_retry_after():
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    sched = MemoryScheduler(
        svc, tick_interval_s=0.002,
        admission=AdmissionPolicy(
            tenants={"acme": TenantPolicy(rate=0.001, burst=2)}))
    fe = MemoryFrontend(svc, KEYS).start()
    try:
        for _ in range(2):
            st, _, _ = _call(fe, "/v1/retrieve",
                             {"namespace": "c", "query": "q"})
            assert st == 200
        st, env, headers = _call(fe, "/v1/retrieve",
                                 {"namespace": "c", "query": "q"})
        assert st == 429
        assert env["reason"] == "rate_limited"
        assert int(headers["Retry-After"]) >= 1
        assert env["retry_after_s"] > 0
        # beta is untouched by acme's limit
        st, _, _ = _call(fe, "/v1/retrieve",
                         {"namespace": "c", "query": "q"}, key="key-beta")
        assert st == 200
    finally:
        fe.close()
        sched.close()


# -- stats --------------------------------------------------------------------

def test_stats_reports_all_layers(frontend):
    _call(frontend, "/v1/record", _record_body())
    st, stats, _ = _call(frontend, "/v1/stats")
    assert st == 200
    assert stats["tenant"] == "acme"
    assert stats["service"]["bank_rows"] >= 1
    assert stats["scheduler"]["ticks"] >= 1
    assert "acme" in stats["scheduler"]["admission"]["tenants"]
    assert stats["frontend"]["requests"] >= 2


# -- SDK client over the wire -------------------------------------------------

def test_http_memory_client_round_trip(frontend):
    mem = HttpMemory(frontend.address, "key-acme", namespace="conv9")
    out = mem.record_session("conv9", "s0", [
        type("M", (), {"speaker": "U", "text": "I live in Osaka.",
                       "timestamp": 1.0})(),
        type("M", (), {"speaker": "U", "text": "I adopted a cat.",
                       "timestamp": 2.0})()])
    assert out["flushed"]
    ctx = mem.retrieve("Which city does the user live in?")
    assert any("osaka" in t.object for t in ctx.triples)
    assert ctx.token_count > 0
    prompt, ctx2 = mem.answer_prompt("Which city?")
    assert ctx2.text in prompt and "Which city?" in prompt
    # the full SDK wrapper composes over the HTTP transport unchanged
    client = MemoriClient(lambda p: "a reply", mem)
    assert client.chat("What pets do I have?") == "a reply"
    client.end_session()


def test_http_memory_raises_admission_error_on_429():
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    sched = MemoryScheduler(
        svc, tick_interval_s=0.002,
        admission=AdmissionPolicy(
            tenants={"acme": TenantPolicy(rate=0.001, burst=1)}))
    fe = MemoryFrontend(svc, KEYS).start()
    try:
        mem = HttpMemory(fe.address, "key-acme")
        mem.retrieve("q")
        with pytest.raises(AdmissionError) as ei:
            mem.retrieve("q")
        assert ei.value.reason == "rate_limited"
        assert ei.value.retry_after_s > 0
    finally:
        fe.close()
        sched.close()


# -- concurrency: many handler threads funnel into shared ticks ---------------

def test_concurrent_http_clients_share_scheduler_ticks(frontend):
    _call(frontend, "/v1/record", _record_body())
    n, errs = 24, []
    barrier = threading.Barrier(n)

    def worker():
        barrier.wait()
        st, env, _ = _call(frontend, "/v1/retrieve",
                           {"namespace": "conv0", "query": "Which city?"})
        if st != 200 or env["status"] != "ok":
            errs.append(env)

    threads = [threading.Thread(target=worker) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    st, stats, _ = _call(frontend, "/v1/stats")
    # batching happened: fewer launches than retrieves
    assert stats["scheduler"]["retrieve_launches"] \
        < stats["scheduler"]["retrieves"]


def _scrape(fe, key="key-acme"):
    req = urllib.request.Request(
        fe.address + "/v1/metrics",
        headers={"Authorization": f"Bearer {key}"})
    with urllib.request.urlopen(req, timeout=30) as r:
        return r.status, r.read().decode(), r.headers


def _parse_exposition(text):
    """Strict parse of a Prometheus text exposition: returns
    (types, helps, samples) where types/helps are keyed by the declared
    metric family name and samples by the full sample name (including any
    `{le="..."}` label)."""
    types, helps, samples = {}, {}, {}
    for ln in text.splitlines():
        if not ln:
            continue
        if ln.startswith("# HELP "):
            name, _, rest = ln[len("# HELP "):].partition(" ")
            helps[name] = rest
        elif ln.startswith("# TYPE "):
            name, _, kind = ln[len("# TYPE "):].partition(" ")
            assert name not in types, f"duplicate TYPE for {name}"
            types[name] = kind
        else:
            assert not ln.startswith("#"), f"unknown comment line: {ln!r}"
            name, _, val = ln.partition(" ")
            float(val)                   # every sample parses as a number
            assert name not in samples, f"duplicate sample {name}"
            samples[name] = val
    return types, helps, samples


def _check_histogram_family(name, samples):
    """Cumulative nondecreasing buckets ending at +Inf == _count, plus a
    _sum — the exact shape promtool requires."""
    buckets = [(k, int(v)) for k, v in samples.items()
               if k.startswith(name + "_bucket{")]
    assert buckets, f"histogram {name} exported no buckets"
    counts = [c for _, c in buckets]
    assert counts == sorted(counts), f"{name} buckets not cumulative"
    assert buckets[-1][0] == name + '_bucket{le="+Inf"}'
    assert int(samples[name + "_count"]) == counts[-1]
    float(samples[name + "_sum"])


def test_metrics_prometheus_exposition(frontend):
    _call(frontend, "/v1/record", _record_body())
    _call(frontend, "/v1/retrieve",
          {"namespace": "conv0", "query": "Which city?"})
    st, text, headers = _scrape(frontend)
    assert st == 200
    assert headers["Content-Type"].startswith("text/plain")
    types, helps, samples = _parse_exposition(text)
    # every family declares a legal type AND a help string
    for name, kind in types.items():
        assert name.startswith("memori_")
        assert kind in ("gauge", "counter", "histogram"), (name, kind)
        assert helps.get(name), f"{name} has no HELP line"
        if kind == "gauge":
            assert name in samples, f"gauge {name} has no sample"
        elif kind == "counter":
            # counters carry the _total suffix on the wire, never bare
            assert name.endswith("_total"), name
            assert name in samples and name[:-len("_total")] not in samples
            assert float(samples[name]) >= 0
        else:
            _check_histogram_family(name, samples)
    # every sample line belongs to a declared family
    for full in samples:
        base = full.split("{", 1)[0]
        for suf in ("_bucket", "_sum", "_count"):
            if base.endswith(suf) and base[:-len(suf)] in types:
                base = base[:-len(suf)]
                break
        assert base in types, f"sample {full} missing TYPE declaration"
    # the layers the dashboard needs are all present
    for want in ("memori_namespaces", "memori_bank_hot_rows",
                 "memori_bank_quant_searches",
                 "memori_scheduler_retrieves",
                 "memori_frontend_requests"):
        assert want in samples, f"missing {want}\n{sorted(samples)[:40]}"
    assert samples["memori_scheduler_retrieves"] == "1"
    assert int(samples["memori_frontend_requests"]) >= 2
    # quantization off in this fixture: the knob is still visible as 0
    assert samples["memori_bank_quantized"] == "0"
    # PR 9: the request-latency histograms ride along on the same scrape
    for hist in ("memori_retrieve_latency_seconds",
                 "memori_record_latency_seconds"):
        assert types.get(hist) == "histogram", f"{hist} not exported"
        assert int(samples[hist + "_count"]) >= 1


def test_metrics_requires_auth(frontend):
    req = urllib.request.Request(frontend.address + "/v1/metrics")
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=30)
    assert ei.value.code == 401


def test_metrics_reports_tier_counters():
    """With quantization + tiering mounted the scrape carries the tier
    gauges a capacity dashboard alerts on."""
    from repro.core.lifecycle import LifecyclePolicy
    from repro.core.tiering import TierPolicy
    svc = MemoryService(EMB, use_kernel=False, budget=800, quantize="int8",
                        policy=LifecyclePolicy(
                            tier=TierPolicy(max_hot_rows=4)))
    svc.runtime._stop.set()
    fe = MemoryFrontend(svc, KEYS).start()
    try:
        _call(fe, "/v1/record", _record_body())
        svc.runtime.run_maintenance_once()
        _, text, _ = _scrape(fe)
        samples = dict(ln.split(" ") for ln in text.splitlines()
                       if not ln.startswith("#"))
        assert samples["memori_bank_quantized"] == "1"
        assert "memori_tiering_demotions" in samples
        assert "memori_tiering_hot_rows" in samples
        assert int(samples["memori_tiering_max_hot_rows"]) == 4
    finally:
        fe.close()
        svc.close(final_snapshot=False)


# -- PR 9: health, readiness, request ids, traces -----------------------------

def _call_raw(fe, path, body=None, headers=None, method=None):
    """Like _call but with caller-controlled headers (no implicit auth)."""
    req = urllib.request.Request(
        fe.address + path,
        data=None if body is None else json.dumps(body).encode(),
        headers=headers or {},
        method=method or ("GET" if body is None else "POST"))
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            return r.status, json.loads(r.read().decode()), r.headers
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read().decode()), e.headers


def _tree_names(trace):
    """Flatten a serialized span tree into the set of span names."""
    out = []

    def walk(sp):
        out.append(sp["name"])
        for c in sp.get("children", ()):
            walk(c)
    walk(trace["root"])
    return out


def test_healthz_and_readyz_unauthenticated(frontend):
    st, body, _ = _call_raw(frontend, "/v1/healthz")
    assert st == 200 and body["status"] == "ok"
    st, body, _ = _call_raw(frontend, "/v1/readyz")
    assert st == 200 and body["status"] == "ok"


def test_readyz_503_while_shard_down():
    svc = MemoryService(EMB, use_kernel=False, budget=800, shards=2)
    fe = MemoryFrontend(svc, KEYS).start()
    try:
        st, _, _ = _call_raw(fe, "/v1/readyz")
        assert st == 200
        svc.set_shard_down(1)
        st, body, _ = _call_raw(fe, "/v1/readyz")
        assert st == 503 and body["status"] == "unavailable"
        assert body["shards_down"] == [1]
        svc.set_shard_up(1)
        st, _, _ = _call_raw(fe, "/v1/readyz")
        assert st == 200
    finally:
        fe.close()


def test_readyz_503_under_reject_backpressure():
    from repro.core.extraction import Message
    from repro.core.lifecycle import LifecyclePolicy
    svc = MemoryService(EMB, use_kernel=False, budget=800,
                        policy=LifecyclePolicy(max_pending=1,
                                               backpressure="reject"))
    svc.runtime._stop.set()              # no background flusher interference
    fe = MemoryFrontend(svc, KEYS).start()
    try:
        svc.enqueue("a/c0", "s0",
                    [Message("U", "I live in Oslo.", 1.0)])
        st, body, _ = _call_raw(fe, "/v1/readyz")
        assert st == 503 and body["backpressure_reject"] is True
        svc.flush()                      # queue drains -> ready again
        st, _, _ = _call_raw(fe, "/v1/readyz")
        assert st == 200
    finally:
        fe.close()
        svc.close(final_snapshot=False)


def test_request_id_honored_and_minted(frontend):
    _call(frontend, "/v1/record", _record_body())
    # caller-supplied X-Request-Id flows into envelope + response header
    st, env, headers = _call_raw(
        frontend, "/v1/retrieve",
        {"namespace": "conv0", "query": "Which city?"},
        headers={"Authorization": "Bearer key-acme",
                 "X-Request-Id": "req-abc.123"})
    assert st == 200
    assert env["request_id"] == "req-abc.123"
    assert headers["X-Request-Id"] == "req-abc.123"
    # absent (or junk) -> the frontend mints one
    st, env, headers = _call(frontend, "/v1/retrieve",
                             {"namespace": "conv0", "query": "Which city?"})
    assert st == 200
    minted = env["request_id"]
    assert minted and headers["X-Request-Id"] == minted
    st, env, _ = _call_raw(
        frontend, "/v1/retrieve",
        {"namespace": "conv0", "query": "Which city?"},
        headers={"Authorization": "Bearer key-acme",
                 "X-Request-Id": "ill egal;header" + "x" * 80})
    assert st == 200 and env["request_id"] != ""


def test_debug_retrieve_returns_complete_span_tree(frontend):
    _call(frontend, "/v1/record", _record_body())
    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"namespace": "conv0", "query": "Which city?",
                        "debug": True})
    assert st == 200
    trace = env["trace"]
    assert trace["request_id"] == env["request_id"]
    assert trace["op"] == "retrieve" and trace["duration_s"] > 0
    names = _tree_names(trace)
    # the full path: frontend -> admission -> queue wait -> shared tick ->
    # every executed plan stage
    for want in ("frontend", "admission", "queued", "scheduler.tick",
                 "plan.embed", "plan.dense", "plan.sparse", "plan.fuse",
                 "plan.budget"):
        assert want in names, f"span {want} missing from {names}"
    # without debug the envelope stays lean
    st, env, _ = _call(frontend, "/v1/retrieve",
                       {"namespace": "conv0", "query": "Which city?"})
    assert st == 200 and "trace" not in env


def test_admin_trace_endpoint():
    svc = MemoryService(EMB, use_kernel=False, budget=800)
    sched = MemoryScheduler(svc, tick_interval_s=0.002, max_batch=16)
    fe = MemoryFrontend(svc, KEYS,
                        admin_keys={"admin-key": "ops"}).start()
    try:
        _call(fe, "/v1/record", _record_body())
        st, _, _ = _call_raw(
            fe, "/v1/retrieve", {"namespace": "conv0", "query": "city?"},
            headers={"Authorization": "Bearer key-acme",
                     "X-Request-Id": "trace-me-1"})
        assert st == 200
        st, body, _ = _call_raw(
            fe, "/v1/admin/trace/trace-me-1",
            headers={"Authorization": "Bearer admin-key"})
        assert st == 200 and body["operator"] == "ops"
        tr = body["trace"]
        assert tr["request_id"] == "trace-me-1"
        assert "scheduler.tick" in _tree_names(tr)
        # tenant keys never reach the admin surface
        st, _, _ = _call_raw(
            fe, "/v1/admin/trace/trace-me-1",
            headers={"Authorization": "Bearer key-acme"})
        assert st == 401
        # unknown request id -> 404
        st, _, _ = _call_raw(
            fe, "/v1/admin/trace/never-issued",
            headers={"Authorization": "Bearer admin-key"})
        assert st == 404
    finally:
        fe.close()
        sched.close()


def test_admin_trace_404_without_keyring(frontend):
    st, _, _ = _call_raw(frontend, "/v1/admin/trace/whatever",
                         headers={"Authorization": "Bearer key-acme"})
    assert st == 404


def test_http_memory_timing_and_traced_retrieve(frontend):
    mem = HttpMemory(frontend.address, "key-acme", namespace="conv7")
    mem.record_session("conv7", "s0", [
        type("M", (), {"speaker": "U", "text": "I live in Turin.",
                       "timestamp": 1.0})()])
    t = mem.last_timing
    assert t["request_id"] and t["service_s"] >= 0 and t["batch_size"] >= 1
    ctx, trace = mem.retrieve_traced("Which city does the user live in?")
    assert any("turin" in tr.object for tr in ctx.triples)
    assert trace["op"] == "retrieve"
    assert "plan.dense" in _tree_names(trace)
    assert mem.last_timing["request_id"] == trace["request_id"]


def test_metrics_exports_all_latency_histograms(tmp_path):
    """The PR 9 acceptance scrape: with a durable service mounted, one
    record + one retrieve over HTTP populate all four latency histograms
    (retrieve/record/flush/fsync) on /v1/metrics."""
    from repro.obs.telemetry import Telemetry, get_telemetry, set_telemetry
    prev = get_telemetry()
    set_telemetry(Telemetry())
    svc = MemoryService(EMB, use_kernel=False, budget=800,
                        data_dir=str(tmp_path / "data"))
    svc.runtime._stop.set()
    sched = MemoryScheduler(svc, tick_interval_s=0.002, max_batch=16)
    fe = MemoryFrontend(svc, KEYS).start()
    try:
        st, _, _ = _call(fe, "/v1/record", _record_body())
        assert st == 200
        st, _, _ = _call(fe, "/v1/retrieve",
                         {"namespace": "conv0", "query": "Which city?"})
        assert st == 200
        _, text, _ = _scrape(fe)
        types, _, samples = _parse_exposition(text)
        for hist in ("memori_retrieve_latency_seconds",
                     "memori_record_latency_seconds",
                     "memori_flush_latency_seconds",
                     "memori_fsync_latency_seconds"):
            assert types.get(hist) == "histogram", f"{hist} not exported"
            assert int(samples[hist + "_count"]) >= 1, hist
            _check_histogram_family(hist, samples)
        # the write path's counters rode along
        assert float(samples["memori_wal_appends_total"]) >= 1
        assert float(samples["memori_wal_fsyncs_total"]) >= 1
    finally:
        fe.close()
        sched.close()
        svc.close(final_snapshot=False)
        set_telemetry(prev)
