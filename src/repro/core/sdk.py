"""Memori SDK — the client wrapper (paper Fig. 1): wraps any LLM callable,
intercepts chat requests, injects retrieved memory as context, and records
the exchange back into memory.  LLM-agnostic by construction: `llm_fn` is
just `prompt -> str` (a repro.serving engine, or anything else).

`memory` is anything with the MemoriMemory read/write surface
(answer_prompt / retrieve / record_session): a standalone MemoriMemory, or —
the production shape — a MemoryService namespace view
(`service.namespace("user/conv")`), so many clients share one packed bank
and the batched retrieval path.  When the backing service has a
MemoryScheduler mounted (`service.start_scheduler()`), every client's
single-question retrieves coalesce with its concurrent peers into one
device launch per scheduler tick — the SDK code does not change."""
from __future__ import annotations

import itertools
import time
from typing import Callable, Optional, Protocol, Tuple

from repro.core.extraction import Message
from repro.core.memory import ANSWER_PROMPT, RetrievedContext

_session_counter = itertools.count()


class MemoryLike(Protocol):
    def answer_prompt(self, question: str) -> Tuple[str, RetrievedContext]: ...
    def retrieve(self, query: str, top_k=None) -> RetrievedContext: ...
    def record_session(self, conversation_id: str, session_id: str,
                       messages) -> object: ...


class MemoriClient:
    def __init__(self, llm_fn: Callable[[str], str], memory: MemoryLike,
                 user_name: str = "user", agent_name: str = "assistant"):
        self.llm = llm_fn
        self.memory = memory
        self.user_name = user_name
        self.agent_name = agent_name
        self._turn_buffer: list[Message] = []

    def chat(self, user_text: str, conversation_id: str = "default",
             timestamp: Optional[float] = None) -> str:
        ts = timestamp if timestamp is not None else time.time()
        prompt, ctx = self.memory.answer_prompt(user_text)
        reply = self.llm(prompt)
        self._turn_buffer.append(Message(self.user_name, user_text, ts))
        self._turn_buffer.append(Message(self.agent_name, reply, ts))
        return reply

    def end_session(self, conversation_id: str = "default",
                    session_id: Optional[str] = None) -> None:
        """Flush the buffered turns through Advanced Augmentation."""
        if not self._turn_buffer:
            return
        sid = session_id or f"s{next(_session_counter)}"
        self.memory.record_session(conversation_id, sid, self._turn_buffer)
        self._turn_buffer = []

    def context_tokens(self, user_text: str) -> int:
        """The Table-2 metric: tokens injected for this query."""
        return self.memory.retrieve(user_text).token_count

    def close(self) -> None:
        """Record any buffered turns, then shut the memory layer down
        cleanly if it is closable (a NamespaceView over a lifecycle-mounted
        MemoryService forwards to `service.close()`: final flush + snapshot
        rotation).  With the runtime's background flusher there is no need
        to call `end_session` in a loop — buffered sessions drain on their
        own; `close()` is the one call a well-behaved client owes on exit."""
        self.end_session()
        closer = getattr(self.memory, "close", None)
        if callable(closer):
            closer()

    def __enter__(self) -> "MemoriClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
