"""Quantized device bank + hot/warm tiered residency (ISSUE 7):
quantize→dequantize round-trip invariants, recall@k vs the f32 oracle on
benign and adversarial distributions, snapshot→restore equivalence, the
zero-recompile / zero-upload residency spies with quantization and tiering
enabled, the cached-labels zero-allocation regression, and the TierManager
policy unit tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.utils import count_compiles
from repro.core import vector_index as vi_mod
from repro.core.embedder import HashEmbedder
from repro.core.extraction import Message
from repro.core.service import MemoryService
from repro.core.tiering import TierManager, TierPolicy
from repro.core.vector_index import VectorIndex, quantize_rows_np
from repro.kernels import ref as kref

RNG = np.random.default_rng(17)


def _f32_oracle_ids(vi, q, q_ns, k):
    """Masked top-k recomputed from the FULL-PRECISION host mirror."""
    eff = np.where(vi.alive(), vi.row_namespaces(), -1)
    _, i = kref.topk_mips_masked_ref(
        jnp.asarray(q), jnp.asarray(vi.bank), jnp.asarray(q_ns, jnp.int32),
        jnp.asarray(eff, jnp.int32), k=min(k, vi.n))
    return np.asarray(i, np.int64)


def _recall(got, want):
    """Mean per-row overlap of live ids."""
    per = []
    for g, w in zip(got, want):
        w = set(int(x) for x in w if x >= 0)
        if not w:
            continue
        g = set(int(x) for x in g if x >= 0)
        per.append(len(g & w) / len(w))
    return float(np.mean(per)) if per else 1.0


# -- quantization round-trip invariants ---------------------------------------

def test_quantize_rows_np_matches_ref_bitwise():
    """The host quantizer (append/promote path) and the jnp ref (oracle +
    materialization contract) must agree bit-for-bit, including the
    zero-row and denormal-ish edge cases."""
    bank = RNG.standard_normal((128, 48)).astype(np.float32)
    bank[3] = 0.0
    bank[7] *= 1e-5
    bank[11] *= 1e4
    c_np, s_np = quantize_rows_np(bank)
    c_ref, s_ref = kref.quantize_rows_ref(bank)
    np.testing.assert_array_equal(c_np, np.asarray(c_ref))
    np.testing.assert_array_equal(s_np, np.asarray(s_ref))


def test_quantize_roundtrip_error_bound_per_row():
    bank = RNG.standard_normal((200, 64)).astype(np.float32) * \
        np.exp(RNG.uniform(-8, 8, size=(200, 1))).astype(np.float32)
    codes, scales = quantize_rows_np(bank)
    recon = codes.astype(np.float32) * scales[:, None]
    assert (np.abs(recon - bank) <= scales[:, None] / 2 + 1e-7).all()


@pytest.mark.parametrize("distribution", ["clustered", "adversarial"])
def test_quantized_search_recall_vs_f32_oracle(distribution):
    """End-to-end recall@10 of the quantized index (fused dequant search +
    exact f32 rescore) vs the f32 oracle must stay >= 0.95 — on a benign
    clustered distribution AND an adversarial one mixing tiny-norm rows
    (scale underflow pressure) with huge-norm outliers (score dominance)."""
    dim, n, k = 48, 600, 10
    if distribution == "clustered":
        centers = RNG.standard_normal((6, dim)).astype(np.float32) * 3
        vecs = (centers[RNG.integers(0, 6, n)]
                + 0.3 * RNG.standard_normal((n, dim))).astype(np.float32)
    else:
        vecs = RNG.standard_normal((n, dim)).astype(np.float32)
        vecs[::11] *= 1e-4                  # tiny-norm rows
        vecs[::17] *= 1e3                   # huge-norm outliers
    ns = RNG.integers(0, 4, n)
    vi_q = VectorIndex(dim=dim, use_kernel=True, quantize="int8", rescore=4)
    vi_q.add(vecs, ns)
    q = RNG.standard_normal((12, dim)).astype(np.float32)
    q_ns = np.arange(12) % 4
    _, i_q = vi_q.search_batch(q, q_ns, k=k)
    want = _f32_oracle_ids(vi_q, q, q_ns, k)
    rec = _recall(np.asarray(i_q), want)
    assert rec >= 0.95, f"recall@{k} = {rec} on {distribution}"


def test_quantized_scores_are_exact_f32():
    """The rescore contract: every score leaving the quantized index is the
    EXACT f32 inner product (quantization can cost recall, never score
    fidelity)."""
    dim = 32
    vi = VectorIndex(dim=dim, use_kernel=True, quantize="int8")
    vecs = RNG.standard_normal((300, dim)).astype(np.float32)
    vi.add(vecs, RNG.integers(0, 3, 300))
    q = RNG.standard_normal((6, dim)).astype(np.float32)
    q_ns = np.arange(6) % 3
    s, i = vi.search_batch(q, q_ns, k=8)
    s, i = np.asarray(s), np.asarray(i)
    for r in range(6):
        for j in range(8):
            if i[r, j] >= 0:
                exact = float(np.float32(q[r]) @ vecs[i[r, j]])
                np.testing.assert_allclose(s[r, j], exact, rtol=1e-5,
                                           atol=1e-5)


def test_quantized_incremental_updates_match_fresh_materialization():
    """add/delete/compact through the donated in-place int8 buffers must
    answer exactly like a fresh index materialized from the same host
    mirror (the dual-buffer invariant)."""
    dim, k = 24, 6
    vi = VectorIndex(dim=dim, capacity=64, use_kernel=True, quantize="int8")
    q = RNG.standard_normal((4, dim)).astype(np.float32)
    q_ns = np.asarray([0, 1, 2, 0], np.int32)

    def check():
        fresh = VectorIndex(dim=dim, capacity=64, use_kernel=True,
                            quantize="int8")
        fresh.load_rows(vi.bank, vi.alive(), ns=vi.row_namespaces())
        _, i1 = vi.search_batch(q, q_ns, k=k)
        _, i2 = fresh.search_batch(q, q_ns, k=k)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    vi.add(RNG.standard_normal((30, dim)).astype(np.float32),
           ns=np.arange(30) % 3)
    check()
    vi.delete([2, 9, 14])
    check()
    vi.add(RNG.standard_normal((80, dim)).astype(np.float32),
           ns=np.arange(80) % 3)            # crosses a capacity boundary
    check()
    vi.delete(np.arange(20, 45))
    vi.compact()
    check()


def test_quantized_snapshot_restore_matches_pre_snapshot_truth(tmp_path):
    """Snapshots are always full-precision: writing one from a quantized
    service and restoring it (quantized again) must preserve the host
    mirror byte-for-byte and answer retrieval identically to the
    pre-snapshot service."""
    path = str(tmp_path / "snap.msgpack")
    svc = MemoryService(HashEmbedder(), use_kernel=True, quantize="int8",
                        budget=800)
    svc.record("a/c0", "s0", [
        Message("Alice", "I live in Tallinn.", 1.0),
        Message("Alice", "I adopted a hedgehog named Biscuit.", 2.0)])
    svc.record("b/c0", "s0", [
        Message("Bob", "I live in Porto.", 1.0),
        Message("Bob", "I work as a welder.", 2.0)])
    queries = [("a/c0", "Which city does the user live in?"),
               ("b/c0", "What is the user's job?"),
               ("a/c0", "What pet was adopted?")]
    before = svc.retrieve_batch(queries)
    bank_before = svc.vindex.bank.copy()
    svc.snapshot(path)
    restored = MemoryService.restore(path, HashEmbedder(), use_kernel=True,
                                     quantize="int8", budget=800)
    # the f32 ground truth survived quantized residency bit-for-bit
    np.testing.assert_array_equal(restored.vindex.bank, bank_before)
    assert restored.vindex.quantize == "int8"
    after = restored.retrieve_batch(queries)
    for got, want in zip(after, before):
        assert got.text == want.text
        assert [t.text() for t in got.triples] == \
            [t.text() for t in want.triples]


# -- residency spies: zero recompiles / zero bank uploads ---------------------

def test_row_labels_device_returns_cached_buffer_no_per_call_alloc(
        monkeypatch):
    """Regression (ISSUE 7 satellite): row_labels_device() used to .copy()
    the cached labels — one fresh device allocation per retrieve.  It must
    return the SAME cached buffer and make zero jnp.asarray calls."""
    vi = VectorIndex(dim=8, capacity=64, use_kernel=False)
    vi.add(RNG.standard_normal((10, 8)).astype(np.float32),
           ns=np.arange(10) % 2)
    first = vi.row_labels_device()           # materializes once
    calls = []
    real_asarray = vi_mod.jnp.asarray

    def spy_asarray(x, *a, **kw):
        calls.append(np.shape(x))
        return real_asarray(x, *a, **kw)

    monkeypatch.setattr(vi_mod.jnp, "asarray", spy_asarray)
    for _ in range(20):
        assert vi.row_labels_device() is first
    assert calls == [], f"per-call label allocations: {calls}"


@pytest.mark.parametrize("quantize", ["none", "int8"])
def test_no_recompile_no_bank_upload_steady_state(quantize, monkeypatch):
    """The acceptance contract survives quantization: appends + searches
    within a capacity bucket reuse one executable set and never move a
    bank-sized buffer host->device.  The spy threshold is capacity*dim
    BYTES — one int8 code-bank upload (cap*dim) trips it, and so does any
    f32 bank (4x bigger); the quantized rescore gather (Q*C*D*4, candidates
    only) stays far below it."""
    dim, cap = 32, 4096
    vi = VectorIndex(dim=dim, capacity=cap, use_kernel=False,
                     quantize=quantize, rescore=2)
    vi.add(RNG.standard_normal((100, dim)).astype(np.float32),
           ns=np.arange(100) % 4)
    q = RNG.standard_normal((4, dim)).astype(np.float32)
    q_ns = np.asarray([0, 1, 2, 3], np.int32)
    # warmup: one search and one single-row append compile the executables
    np.asarray(vi.search_batch(q, q_ns, k=8)[1])
    vi.add(RNG.standard_normal((1, dim)).astype(np.float32), ns=[0])
    np.asarray(vi.search_batch(q, q_ns, k=8)[1])

    uploads = []
    real_asarray = vi_mod.jnp.asarray

    def spy_asarray(x, *a, **kw):
        if getattr(x, "nbytes", 0) >= cap * dim:
            uploads.append((np.shape(x), getattr(x, "dtype", None)))
        return real_asarray(x, *a, **kw)

    monkeypatch.setattr(vi_mod.jnp, "asarray", spy_asarray)
    with count_compiles() as cc:
        for _ in range(40):
            vi.add(RNG.standard_normal((1, dim)).astype(np.float32), ns=[1])
            _, i = vi.search_batch(q, q_ns, k=8)
        np.asarray(i)
    assert cc.count == 0, f"recompiled {cc.count}x: {cc.msgs[:3]}"
    assert uploads == [], f"bank-sized host->device transfers: {uploads}"


@pytest.mark.parametrize("quantize", ["none", "int8"])
def test_tiering_demote_promote_steady_state_no_recompile_no_upload(
        quantize, monkeypatch):
    """Demotion/promotion cycles of a warmed size are in-place pow2
    scatters: zero recompiles, zero bank-sized transfers — tier churn never
    degrades the residency guarantees."""
    dim, cap = 32, 4096
    vi = VectorIndex(dim=dim, capacity=cap, use_kernel=False,
                     quantize=quantize, rescore=2)
    vi.add(RNG.standard_normal((120, dim)).astype(np.float32),
           ns=np.arange(120) % 4)
    q = RNG.standard_normal((4, dim)).astype(np.float32)
    q_ns = np.asarray([0, 1, 2, 3], np.int32)
    rows_ns0 = vi.rows_in_namespace(0)
    # warmup: one demote/promote/search cycle compiles the executables
    np.asarray(vi.search_batch(q, q_ns, k=8)[1])
    vi.demote_rows(rows_ns0)
    np.asarray(vi.search_batch(q, q_ns, k=8)[1])
    vi.promote_rows(rows_ns0)
    np.asarray(vi.search_batch(q, q_ns, k=8)[1])

    uploads = []
    real_asarray = vi_mod.jnp.asarray

    def spy_asarray(x, *a, **kw):
        if getattr(x, "nbytes", 0) >= cap * dim:
            uploads.append(np.shape(x))
        return real_asarray(x, *a, **kw)

    monkeypatch.setattr(vi_mod.jnp, "asarray", spy_asarray)
    with count_compiles() as cc:
        for _ in range(10):
            assert vi.demote_rows(rows_ns0) == len(rows_ns0)
            _, i = vi.search_batch(q, q_ns, k=8)
            assert vi.promote_rows(rows_ns0) == len(rows_ns0)
            _, i = vi.search_batch(q, q_ns, k=8)
        np.asarray(i)
    assert cc.count == 0, f"recompiled {cc.count}x: {cc.msgs[:3]}"
    assert uploads == [], f"bank-sized transfers during tier churn: {uploads}"


# -- tiered residency semantics ----------------------------------------------

@pytest.mark.parametrize("quantize", ["none", "int8"])
def test_demote_promote_round_trip_preserves_answers(quantize):
    dim, k = 24, 8
    vi = VectorIndex(dim=dim, use_kernel=True, quantize=quantize)
    vecs = RNG.standard_normal((200, dim)).astype(np.float32)
    ns = RNG.integers(0, 4, 200)
    vi.add(vecs, ns)
    q = RNG.standard_normal((8, dim)).astype(np.float32)
    q_ns = np.arange(8) % 4
    s0, i0 = vi.search_masked(q, q_ns, ns, k=k)
    rows = vi.rows_in_namespace(1)
    assert vi.demote_rows(rows) == len(rows)
    assert vi.n_warm == len(rows)
    s1, i1 = vi.search_masked(q, q_ns, ns, k=k)
    for r in range(8):
        if q_ns[r] == 1:
            assert (i1[r] == -1).all(), "demoted namespace still surfaced"
    # host fallback answers from the full-precision mirror, warm included
    sh, ih = vi.search_host(q, q_ns, k=k)
    np.testing.assert_array_equal(ih, i0)
    assert vi.promote_rows(rows) == len(rows)
    s2, i2 = vi.search_masked(q, q_ns, ns, k=k)
    np.testing.assert_array_equal(i2, i0)
    np.testing.assert_allclose(s2, s0, rtol=1e-6)


def test_tier_state_survives_compaction():
    dim = 16
    vi = VectorIndex(dim=dim, use_kernel=False)
    vecs = RNG.standard_normal((90, dim)).astype(np.float32)
    ns = np.arange(90) % 3
    vi.add(vecs, ns)
    vi.demote_rows(vi.rows_in_namespace(2))
    warm_before = vi.n_warm
    vi.delete(vi.rows_in_namespace(0))
    vi.compact()
    assert vi.n_warm == warm_before, "compaction lost the warm tier"
    q = RNG.standard_normal((3, dim)).astype(np.float32)
    _, i = vi.search_masked(q, np.asarray([2, 1, 2]), vi.row_namespaces(),
                            k=4)
    assert (i[0] == -1).all() and (i[2] == -1).all()
    assert (i[1] >= 0).any()


def test_tier_manager_ewma_decay_and_coldest_first():
    """Policy unit test on a fake clock: activity decays with the
    configured halflife and demotion picks the coldest namespaces."""
    now = [0.0]
    vi = VectorIndex(dim=8, use_kernel=False)
    vi.add(RNG.standard_normal((40, 8)).astype(np.float32),
           ns=np.arange(40) % 4)
    tm = TierManager(vi, TierPolicy(max_hot_rows=20, halflife_s=10.0),
                     clock=lambda: now[0])
    tm.note_retrieve(0)
    tm.note_retrieve(0)
    tm.note_retrieve(1)
    assert tm.score(0) == pytest.approx(2.0)
    now[0] = 10.0                            # one halflife
    assert tm.score(0) == pytest.approx(1.0)
    assert tm.score(3) == 0.0                # never seen
    did = tm.tick()                          # 40 hot > 20 budget
    assert did["demoted_rows"] == 20 and did["demoted_ns"] == 2
    # the two never-retrieved namespaces went cold first
    assert tm.demoted_namespaces() == {2, 3}
    assert vi.n_resident == 20
    # a fallback marks ns 2; the next tick promotes it and re-demotes the
    # now-coldest resident namespace to hold the budget
    tm.note_host_fallback(2)
    assert tm.counters["host_fallbacks"] == 1
    did = tm.tick()
    assert did["promoted_ns"] == 1 and not tm.is_demoted(2)
    assert vi.n_resident <= 20


def test_tier_manager_within_budget_never_demotes():
    vi = VectorIndex(dim=8, use_kernel=False)
    vi.add(RNG.standard_normal((10, 8)).astype(np.float32),
           ns=np.arange(10) % 2)
    tm = TierManager(vi, TierPolicy(max_hot_rows=100))
    did = tm.tick()
    assert did["demoted_ns"] == 0 and vi.n_warm == 0


def test_service_host_fallback_and_promotion_cycle():
    """Service-level: retrieving a demoted namespace transparently answers
    from the host mirror (same triples as when hot), counts a fallback,
    and the next maintenance tick promotes the namespace back."""
    from repro.core.lifecycle import LifecyclePolicy
    svc = MemoryService(HashEmbedder(), use_kernel=False, quantize="int8",
                        budget=800,
                        policy=LifecyclePolicy(
                            tier=TierPolicy(max_hot_rows=4)))
    svc.runtime._stop.set()                  # drive maintenance manually
    for u, city in enumerate(["Tallinn", "Porto", "Cusco"]):
        svc.record(f"u{u}/c0", "s0", [
            Message(f"U{u}", f"I live in {city}.", 1.0),
            Message(f"U{u}", "I work as a welder.", 2.0)])
    q = "Which city does the user live in?"
    hot_answers = {u: svc.retrieve(f"u{u}/c0", q).text for u in range(3)}
    svc.runtime.run_maintenance_once()       # forces demotions (budget 4)
    tiers = svc.store.tiers
    assert tiers.demoted_namespaces(), "nothing demoted despite tiny budget"
    demoted_ns = next(iter(tiers.demoted_namespaces()))
    name = next(ns for ns, t in svc.store._tenants.items()
                if t.ns_id == demoted_ns)
    got = svc.retrieve(name, q)
    assert got.text == hot_answers[int(name[1])], \
        "host fallback answered differently from the hot path"
    assert tiers.counters["host_fallbacks"] >= 1
    svc.runtime.run_maintenance_once()
    assert not tiers.is_demoted(demoted_ns)
    assert svc.retrieve(name, q).text == hot_answers[int(name[1])]
    svc.close()
