"""Sharded exact-MIPS vector index — the FAISS replacement (DESIGN.md §3).

Single-device search runs the fused Pallas topk_mips kernel.  On a mesh, the
bank rows shard across every device (logical axis "bank"); search is the
classic distributed-ANN reduction expressed in shard_map:

    local top-k per shard  →  all_gather(k·shards candidates)  →  re-rank

Exact search is the right call *because of the paper*: Advanced Augmentation
compresses raw dialogue into triples, keeping the bank orders of magnitude
smaller than chunk-RAG banks — small enough that exact MIPS at full HBM
bandwidth beats approximate pointer-chasing structures on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import ops as kops
from repro.kernels import ref as kref


class VectorIndex:
    def __init__(self, dim: int, capacity: int = 1024, use_kernel: bool = True):
        self.dim = dim
        self.n = 0
        self.use_kernel = use_kernel
        self._bank = np.zeros((capacity, dim), np.float32)

    def add(self, vecs) -> np.ndarray:
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        m = vecs.shape[0]
        while self.n + m > self._bank.shape[0]:
            self._bank = np.concatenate(
                [self._bank, np.zeros_like(self._bank)], axis=0)
        ids = np.arange(self.n, self.n + m)
        self._bank[self.n: self.n + m] = vecs
        self.n += m
        return ids

    @property
    def bank(self) -> np.ndarray:
        return self._bank[: self.n]

    def search(self, queries, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """queries (Q, D) -> (scores (Q, k), ids (Q, k)); ids == -1 beyond n."""
        queries = jnp.asarray(queries, jnp.float32)
        if queries.ndim == 1:
            queries = queries[None]
        if self.n == 0:
            Q = queries.shape[0]
            return (np.full((Q, k), -np.inf, np.float32),
                    np.full((Q, k), -1, np.int64))
        bank = jnp.asarray(self.bank)
        kk = min(k, self.n)
        if self.use_kernel:
            s, i = kops.topk_mips(queries, bank, k=kk)
        else:
            s, i = kref.topk_mips_ref(queries, bank, k=kk)
        s = np.asarray(s)
        i = np.asarray(i, np.int64)
        if kk < k:
            s = np.pad(s, ((0, 0), (0, k - kk)), constant_values=-np.inf)
            i = np.pad(i, ((0, 0), (0, k - kk)), constant_values=-1)
        return s, i


# ---------------------------------------------------------------------------
# Distributed search (shard_map): used by launch/dryrun and on real meshes.
# ---------------------------------------------------------------------------

def sharded_topk(queries, bank, k: int, mesh: Mesh, axis_names=("data", "model")):
    """bank rows sharded over `axis_names` (flattened); returns global
    (scores (Q,k), ids (Q,k)).  Local top-k → all_gather → re-rank."""
    flat_axes = tuple(a for a in axis_names if a in mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in flat_axes]))
    N = bank.shape[0]
    assert N % n_shards == 0, (N, n_shards)
    shard_rows = N // n_shards

    def local(q, b):
        # positional index of this shard along the flattened bank axes
        idx = jax.lax.axis_index(flat_axes)
        s, i = kref.topk_mips_ref(q, b, k=min(k, shard_rows))
        i = i + idx * shard_rows
        # gather candidates from every shard, then re-rank globally
        s_all = jax.lax.all_gather(s, flat_axes, axis=1, tiled=True)
        i_all = jax.lax.all_gather(i, flat_axes, axis=1, tiled=True)
        top_s, pos = jax.lax.top_k(s_all, k)
        top_i = jnp.take_along_axis(i_all, pos, axis=1)
        return top_s, top_i

    spec_bank = P(flat_axes)
    # outputs are replicated by construction (all_gather + local re-rank);
    # check_vma can't prove it, so we assert it ourselves
    fn = jax.shard_map(local, mesh=mesh,
                       in_specs=(P(), spec_bank),
                       out_specs=(P(), P()), check_vma=False)
    return fn(queries, bank)
