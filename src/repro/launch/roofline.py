"""Roofline machinery for the dry-run.

XLA's cost analysis counts a lax.scan (while-loop) body ONCE regardless of
trip count, so scanned-layer models under-report FLOPs/collectives.  The fix:
compile small *unrolled* probe configs (force_unroll=True), express each probe
as a layer-kind composition vector, solve the linear model

    metric(config) = intercept + Σ_kind  n_kind · coeff_kind

by least squares, and predict the full config exactly (probe compositions are
chosen so the full-config vector lies in their span).  Memory analysis comes
from the full compile (layout/liveness are layer-count independent under
scan); FLOPs, bytes-accessed and collective bytes come from the probe model.

Roofline terms per (arch × shape) on the single-pod mesh (TPU v5e):
    compute_s    = HLO_FLOPs_per_chip   / 197e12
    memory_s     = HLO_bytes_per_chip   / 819e9
    collective_s = coll_bytes_per_chip  / 50e9
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Tuple

import numpy as np

from repro.launch import mesh as mesh_lib
from repro.models.config import InputShape, ModelConfig


def probe_layer_plans(cfg: ModelConfig) -> List[Dict[str, int]]:
    """Probe configs: {'num_layers': L, 'encoder_layers': E} overrides."""
    if cfg.is_encoder_decoder:
        return [{"num_layers": 1, "encoder_layers": 1},
                {"num_layers": 2, "encoder_layers": 1},
                {"num_layers": 1, "encoder_layers": 2}]
    if cfg.hybrid_period > 0:
        p = cfg.hybrid_period
        return [{"num_layers": 1}, {"num_layers": p}, {"num_layers": 2 * p}]
    if cfg.first_k_dense > 0:
        k = cfg.first_k_dense
        return [{"num_layers": k}, {"num_layers": k + 1}, {"num_layers": k + 2}]
    return [{"num_layers": 1}, {"num_layers": 2}]


def composition_vector(cfg: ModelConfig, keys: List[str]) -> np.ndarray:
    counts = Counter(f"{m}/{f}" for m, f in cfg.layer_kinds())
    counts["_intercept"] = 1
    counts["_encoder"] = cfg.encoder_layers if cfg.is_encoder_decoder else 0
    return np.array([float(counts.get(k, 0)) for k in keys])


def composition_keys(cfg: ModelConfig) -> List[str]:
    kinds = sorted(set(f"{m}/{f}" for m, f in cfg.layer_kinds()))
    keys = ["_intercept"] + kinds
    if cfg.is_encoder_decoder:
        keys.append("_encoder")
    return keys


def probe_configs(cfg: ModelConfig) -> List[ModelConfig]:
    out = []
    for plan in probe_layer_plans(cfg):
        # mtp (deepseek) stays on: it is layer-count-constant, so it lands in
        # the intercept and the prediction includes it exactly once.
        out.append(dataclasses.replace(cfg, force_unroll=True, **plan))
    return out


def extrapolate(cfg: ModelConfig, probe_cfgs: List[ModelConfig],
                probe_metrics: List[Dict[str, float]]) -> Dict[str, float]:
    """Least-squares solve + predict for every metric key."""
    keys = composition_keys(cfg)
    A = np.stack([composition_vector(c, keys) for c in probe_cfgs])
    target = composition_vector(cfg, keys)
    out = {}
    metric_names = probe_metrics[0].keys()
    for name in metric_names:
        y = np.array([m[name] for m in probe_metrics])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        out[name] = float(max(0.0, target @ coef))
    return out


def roofline_terms(per_chip_flops: float, per_chip_bytes: float,
                   per_chip_coll_bytes: float) -> Dict[str, float]:
    compute_s = per_chip_flops / mesh_lib.PEAK_FLOPS_BF16
    memory_s = per_chip_bytes / mesh_lib.HBM_BW
    collective_s = per_chip_coll_bytes / mesh_lib.ICI_BW_PER_LINK
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(compute_s, memory_s, collective_s)
    terms["bound_s"] = total
    return terms
