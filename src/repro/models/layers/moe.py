"""Mixture-of-Experts FFN with capacity-bounded, sort-free dispatch.

TPU-native design notes (DESIGN.md §3):
  * Dispatch is gather/scatter based (cumsum rank within expert), NOT the
    GShard one-hot-matmul form — the one-hot einsum costs T*E*C*d FLOPs and
    would dwarf the useful expert FLOPs for 256-expert DeepSeek configs;
    gather/scatter keeps HLO FLOP counts honest for the roofline.
  * Experts shard over the `model` mesh axis (expert parallel).  Tokens are
    sharded over `data`; the (E, C, d) buffers are sharding-constrained to
    `experts -> model`, so SPMD lowers the exchange to all-to-all style
    collectives.
  * Supports shared experts (DeepSeek: 1 always-on) and top-k routing with
    switch-style load-balance + router-z auxiliary losses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.module import ParamSpec
from repro.common.utils import round_up
from repro.models.layers import mlp


def specs(cfg):
    m = cfg.moe
    d = cfg.d_model
    ff = m.d_ff_expert or cfg.d_ff
    s = {
        "router": ParamSpec((d, m.num_experts), ("embed", None),
                            init="scaled_normal", scale=1.0),
        "wi": ParamSpec((m.num_experts, d, ff), ("experts", "embed", "ff"),
                        init="scaled_normal", scale=1.0),
        "wo": ParamSpec((m.num_experts, ff, d), ("experts", "ff", "embed"),
                        init="scaled_normal", scale=1.0),
    }
    if cfg.mlp_gated:
        s["wg"] = ParamSpec((m.num_experts, d, ff), ("experts", "embed", "ff"),
                            init="scaled_normal", scale=1.0)
    if m.num_shared_experts:
        s["shared"] = mlp.specs(cfg, d_ff=ff * m.num_shared_experts)
    return s


def _capacity(cfg, tokens: int) -> int:
    m = cfg.moe
    cap = int(m.capacity_factor * tokens * m.experts_per_token / m.num_experts)
    return max(8, round_up(cap, 8))


def _expert_ffn(params, cfg, buf):
    dt = buf.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"].astype(dt))
    if cfg.mlp_gated:
        g = jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(dt))
        h = (jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)) * h
    else:
        h = jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(dt))


def _rank_in_expert(flat_sel, E):
    """GShard-style rank: position of each routed slot within its expert
    (one-hot cumsum over the token dim — gather/scatter, no one-hot matmul)."""
    oh = (flat_sel[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    ranks = jnp.cumsum(oh, axis=0)
    return jnp.take_along_axis(ranks, flat_sel[:, None], axis=1)[:, 0] - 1


def _dispatch_global(params, cfg, xt, gate_vals, sel, rules):
    """Baseline: one global capacity ranking + scatter into (E, C, d)."""
    from repro.common.partitioning import shard_constraint
    m = cfg.moe
    T, d = xt.shape
    E, K = m.num_experts, m.experts_per_token
    C = _capacity(cfg, T)
    dt = xt.dtype

    flat_sel = sel.reshape(-1)                                  # (T*K,)
    pos_in_expert = _rank_in_expert(flat_sel, E)
    keep = pos_in_expert < C
    slot = flat_sel * C + jnp.where(keep, pos_in_expert, 0)

    xk = jnp.repeat(xt, K, axis=0)                              # (T*K, d)
    contrib = jnp.where(keep[:, None], xk, 0).astype(dt)
    buf = jnp.zeros((E * C, d), dt).at[slot].add(contrib)
    buf = buf.reshape(E, C, d)
    if rules is not None:
        buf = shard_constraint(buf, rules, "experts", "expert_cap", None)
    out_buf = _expert_ffn(params, cfg, buf)
    if rules is not None:
        out_buf = shard_constraint(out_buf, rules, "experts", "expert_cap", None)
    out_buf = out_buf.reshape(E * C, d)

    yk = out_buf[slot]
    yk = yk * (gate_vals.reshape(-1)[:, None] * keep[:, None]).astype(dt)
    return yk.reshape(T, K, d).sum(1), keep


def _dispatch_local(params, cfg, xt, gate_vals, sel, rules):
    """§Perf variant: per-data-shard ranking + vmap'd local scatter.

    Tokens are viewed as (n_shards, T_loc, d) with the shard dim pinned to
    the data axis; ranking/capacity/scatter happen *within* a shard (vmap ->
    per-device local ops under SPMD).  Only the (E, n_shards·C_loc, d)
    exchange crosses chips — the true MoE all-to-all — instead of the global
    scatter's materialised cross-shard buffer reductions."""
    from repro.common.partitioning import shard_constraint
    m = cfg.moe
    T, d = xt.shape
    E, K = m.num_experts, m.experts_per_token
    n_sh = max(1, m.local_shards)
    assert T % n_sh == 0, (T, n_sh)
    T_loc = T // n_sh
    C_loc = max(8, _capacity(cfg, T_loc))
    dt = xt.dtype

    xs = xt.reshape(n_sh, T_loc, d)
    sel_s = sel.reshape(n_sh, T_loc * K)
    gv_s = gate_vals.reshape(n_sh, T_loc * K)
    if rules is not None:
        xs = shard_constraint(xs, rules, "batch", None, None)

    def shard_dispatch(x_row, sel_row):
        pos = _rank_in_expert(sel_row, E)
        keep_row = pos < C_loc
        slot_row = sel_row * C_loc + jnp.where(keep_row, pos, 0)
        xk = jnp.repeat(x_row, K, axis=0)
        contrib = jnp.where(keep_row[:, None], xk, 0).astype(dt)
        buf_row = jnp.zeros((E * C_loc, d), dt).at[slot_row].add(contrib)
        return buf_row.reshape(E, C_loc, d), keep_row, slot_row

    bufs, keeps, slots = jax.vmap(shard_dispatch)(xs, sel_s)
    # (n_sh, E, C_loc, d): local so far; the transpose+constraint below is
    # the all-to-all (data-major -> expert-major layout).
    if rules is not None:
        bufs = shard_constraint(bufs, rules, "batch", None, None, None)
    buf_e = bufs.transpose(1, 0, 2, 3).reshape(E, n_sh * C_loc, d)
    if rules is not None:
        # 2D expert × capacity sharding (GShard layout): experts over model,
        # each expert's capacity over data — otherwise the data axis idles
        # during the expert FFN (16x per-chip FLOPs; §Perf iteration 2).
        buf_e = shard_constraint(buf_e, rules, "experts", "expert_cap", None)

    out_e = _expert_ffn(params, cfg, buf_e)
    if rules is not None:
        out_e = shard_constraint(out_e, rules, "experts", "expert_cap", None)
    out_s = out_e.reshape(E, n_sh, C_loc, d).transpose(1, 0, 2, 3)
    if rules is not None:
        out_s = shard_constraint(out_s, rules, "batch", None, None, None)

    def shard_combine(buf_row, slot_row, keep_row, gv_row):
        yk = buf_row.reshape(E * C_loc, d)[slot_row]
        yk = yk * (gv_row[:, None] * keep_row[:, None]).astype(dt)
        return yk.reshape(T_loc, K, d).sum(1)

    ys = jax.vmap(shard_combine)(out_s, slots, keeps, gv_s)
    return ys.reshape(T, d), keeps.reshape(-1)


def apply(params, cfg, x, *, rules=None):
    """x: (B,S,d) -> (y (B,S,d), aux_losses dict)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.num_experts, m.experts_per_token
    dt = x.dtype
    xt = x.reshape(T, d)

    # Router (fp32 for stable softmax).
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, K)                   # (T,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Aux losses (switch-transformer load balance + router z-loss).
    me = probs.mean(0)                                          # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[sel.reshape(-1)].add(1.0) / (T * K)
    lb_loss = E * jnp.sum(me * ce) * m.load_balance_coef
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_coef

    dispatch = _dispatch_local if m.dispatch == "local" else _dispatch_global
    y, keep = dispatch(params, cfg, xt, gate_vals, sel, rules)

    if m.num_shared_experts:
        y = y + mlp.apply(params["shared"], cfg, xt)

    aux = {"moe_load_balance": lb_loss, "moe_router_z": z_loss,
           "moe_drop_fraction": 1.0 - keep.mean()}
    return y.reshape(B, S, d), aux
