"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def topk_mips_ref(queries, bank, k: int = 32, n_valid=None):
    """queries (Q,D), bank (N,D) -> (scores (Q,k) f32, indices (Q,k) i32).
    With `n_valid` (traced i32 scalar), rows >= n_valid are padding: they
    score NEG_INF and report index -1 — matching the kernel's stable-shape
    contract over capacity-padded banks."""
    s = jnp.einsum("qd,nd->qn", queries.astype(jnp.float32),
                   bank.astype(jnp.float32))
    if n_valid is not None:
        col = jnp.arange(bank.shape[0], dtype=jnp.int32)[None, :]
        s = jnp.where(col < n_valid, s, NEG_INF)
    scores, idx = jax.lax.top_k(s, k)
    if n_valid is not None:
        idx = jnp.where(scores > NEG_INF / 2, idx, -1)
    return scores, idx.astype(jnp.int32)


def quantize_rows_ref(bank):
    """Symmetric per-row int8 quantization (the contract the quantized
    kernels score against): scale = max|row| / 127, q = round(row / scale)
    clipped to [-127, 127]; an all-zero row gets scale 0 and zero codes.
    Returns (codes int8 (N, D), scales f32 (N,)).  Shared by the
    VectorIndex quantizer and the oracle tests — per-element dequant error
    is bounded by scale/2."""
    bank = jnp.asarray(bank, jnp.float32)
    amax = jnp.max(jnp.abs(bank), axis=1)
    scale = amax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    codes = jnp.clip(jnp.round(bank * inv[:, None]), -127, 127)
    return codes.astype(jnp.int8), scale


def _quant_scores(queries, bank_i8, scales):
    """(Q, N) f32 scores in the fused kernel's exact operation order:
    contract the int8 codes in f32, THEN multiply by the row scale —
    `(q · row_i8) * scale`, not `q · (scale * row_i8)` — so oracle and
    kernel agree to the same rounding and index comparisons stay exact."""
    s = jnp.einsum("qd,nd->qn", jnp.asarray(queries, jnp.float32),
                   jnp.asarray(bank_i8).astype(jnp.float32))
    return s * jnp.asarray(scales, jnp.float32)[None, :]


def topk_mips_quant_ref(queries, bank_i8, scales, k: int = 32, n_valid=None):
    """Quantized-MIPS oracle: top-k over the fused dequant scores."""
    s = _quant_scores(queries, bank_i8, scales)
    if n_valid is not None:
        col = jnp.arange(bank_i8.shape[0], dtype=jnp.int32)[None, :]
        s = jnp.where(col < n_valid, s, NEG_INF)
    scores, idx = jax.lax.top_k(s, k)
    if n_valid is not None:
        idx = jnp.where(scores > NEG_INF / 2, idx, -1)
    return scores, idx.astype(jnp.int32)


def topk_mips_quant_masked_ref(queries, bank_i8, scales, q_ns, bank_ns,
                               k: int = 32, n_valid=None):
    """Namespace-masked quantized-MIPS oracle (see topk_mips_quant_ref)."""
    s = _quant_scores(queries, bank_i8, scales)
    ok = jnp.asarray(q_ns, jnp.int32)[:, None] == \
        jnp.asarray(bank_ns, jnp.int32)[None, :]
    if n_valid is not None:
        col = jnp.arange(bank_i8.shape[0], dtype=jnp.int32)[None, :]
        ok = ok & (col < n_valid)
    s = jnp.where(ok, s, NEG_INF)
    scores, idx = jax.lax.top_k(s, k)
    idx = jnp.where(scores > NEG_INF / 2, idx, -1)
    return scores, idx.astype(jnp.int32)


def topk_mips_masked_ref(queries, bank, q_ns, bank_ns, k: int = 32,
                         n_valid=None):
    """Namespace-masked MIPS oracle: cross-namespace scores become NEG_INF
    and their indices -1 (matching the kernel, whose running top-k never
    admits a masked column).  q_ns (Q,) i32 >= 0; bank_ns (N,) i32 with -1
    marking tombstoned rows.  `n_valid` bounds the live bank prefix of a
    capacity-padded bank, as in topk_mips_ref."""
    s = jnp.einsum("qd,nd->qn", queries.astype(jnp.float32),
                   bank.astype(jnp.float32))
    ok = jnp.asarray(q_ns, jnp.int32)[:, None] == \
        jnp.asarray(bank_ns, jnp.int32)[None, :]
    if n_valid is not None:
        col = jnp.arange(bank.shape[0], dtype=jnp.int32)[None, :]
        ok = ok & (col < n_valid)
    s = jnp.where(ok, s, NEG_INF)
    scores, idx = jax.lax.top_k(s, k)
    idx = jnp.where(scores > NEG_INF / 2, idx, -1)
    return scores, idx.astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale=None):
    """q: (B,K,G,S,D); k,v: (B,K,T,D) -> (B,K,G,S,D)."""
    B, K, G, S, D = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bkgsd,bktd->bkgst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window > 0:
        ok = ok & (k_pos > q_pos - window)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len, *, scale=None, window: int = 0):
    """q: (B,K,G,D); k,v: (B,K,T,D); kv_len (B,) -> (B,K,G,D)."""
    B, K, G, D = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(T)[None, None, None, :]
    kl = kv_len[:, None, None, None]
    ok = pos < kl
    if window > 0:
        ok = ok & (pos > kl - 1 - window)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
