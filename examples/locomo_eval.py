"""Reproduce the paper's evaluation protocol on the synthetic LoCoMo
benchmark: Memori vs baselines, accuracy by category + token accounting.

    PYTHONPATH=src python examples/locomo_eval.py [--seeds 2] [--sessions 10]
"""
import argparse

from benchmarks.common import evaluate
from repro.data.locomo_synth import CATEGORIES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--sessions", type=int, default=10)
    ap.add_argument("--budget", type=int, default=1300)
    args = ap.parse_args()

    systems = ["memori", "memori-triples-only", "memori-dense-only",
               "memori-bm25-only", "rag", "full-context"]
    print(f"{'method':22s} " + " ".join(f"{c:>11s}" for c in CATEGORIES)
          + f" {'overall':>8s} {'tokens':>7s}")
    for name in systems:
        r = evaluate(name, seeds=tuple(range(args.seeds)),
                     n_sessions=args.sessions, budget=args.budget)
        cols = " ".join(f"{100*r.per_category[c]:10.2f}%" for c in CATEGORIES)
        print(f"{name:22s} {cols} {100*r.overall:7.2f}% {r.mean_tokens:7.0f}")


if __name__ == "__main__":
    main()
