"""Memori SDK — the client wrapper (paper Fig. 1): wraps any LLM callable,
intercepts chat requests, injects retrieved memory as context, and records
the exchange back into memory.  LLM-agnostic by construction: `llm_fn` is
just `prompt -> str` (a repro.serving engine, or anything else).

`memory` is anything with the MemoriMemory read/write surface
(answer_prompt / retrieve / record_session): a standalone MemoriMemory, or —
the production shape — a MemoryService namespace view
(`service.namespace("user/conv")`), so many clients share one packed bank
and the batched retrieval path.  When the backing service has a
MemoryScheduler mounted (`service.start_scheduler()`), every client's
single-question retrieves coalesce with its concurrent peers into one
device launch per scheduler tick — the SDK code does not change."""
from __future__ import annotations

import dataclasses
import http.client
import itertools
import json
import random
import time
import urllib.error
import urllib.request
from typing import Callable, Optional, Protocol, Tuple

from repro.core.admission import AdmissionError
from repro.core.extraction import Message
from repro.core.memory import ANSWER_PROMPT, RetrievedContext
from repro.core.summaries import Summary
from repro.core.triples import Triple

_session_counter = itertools.count()


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry for the HTTP client's transient failures.

    Retried: 429 (admission control — honoring the server's Retry-After
    hint), 5xx, and connection-level failures (reset, refused, timeout).
    Never retried: every other 4xx — the request itself is wrong, and a
    retry would just fail again (or worse, double-apply a write the
    server already rejected for a reason).  Backoff is exponential with
    full jitter (`base * 2^attempt * uniform(1-jitter, 1)`), capped at
    `max_backoff_s`; a server Retry-After hint REPLACES the computed
    backoff (capped the same way).  `max_attempts` counts tries, not
    retries: 4 means 1 try + up to 3 retries."""
    max_attempts: int = 4
    base_backoff_s: float = 0.05
    max_backoff_s: float = 2.0
    jitter: float = 0.5
    retry_rate_limited: bool = True

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff bounds must be >= 0")
        if not 0 <= self.jitter <= 1:
            raise ValueError("jitter must be in [0, 1]")

    def backoff_s(self, attempt: int, rng: random.Random,
                  retry_after_s: Optional[float] = None) -> float:
        """Sleep before retry number `attempt` (0-based)."""
        if retry_after_s is not None:
            return min(self.max_backoff_s, max(0.0, retry_after_s))
        raw = self.base_backoff_s * (2.0 ** attempt)
        if self.jitter:
            raw *= rng.uniform(1.0 - self.jitter, 1.0)
        return min(self.max_backoff_s, raw)


class MemoryLike(Protocol):
    def answer_prompt(self, question: str) -> Tuple[str, RetrievedContext]: ...
    def retrieve(self, query: str, top_k=None) -> RetrievedContext: ...
    def record_session(self, conversation_id: str, session_id: str,
                       messages) -> object: ...


class HttpMemory:
    """MemoryLike over the HTTP frontend (serving/frontend.py): the same
    SDK client, pointed at a remote memory service instead of an in-process
    one.  `namespace` is the *client* namespace — the server scopes it
    under the tenant the api key resolves to, so two keys can use the same
    namespace string without ever seeing each other's memories.

    Transient failures are retried under a bounded `RetryPolicy`
    (exponential backoff + full jitter): QoS rejections (HTTP 429) back
    off by the server's Retry-After hint, 5xx and connection-level
    failures (reset / refused / timeout) by the computed backoff.  Once
    attempts are exhausted the last failure surfaces unchanged — 429 as
    `AdmissionError` with the server's `reason` and `retry_after_s` (the
    same exception an in-process submit raises, so caller backoff logic
    is transport-agnostic).  Stdlib urllib only; one request per call
    (the server side batches across clients, which is where the
    economics live)."""

    # connection-level failures worth retrying: the request may never have
    # reached the server (refused, reset, DNS) or died mid-flight
    _TRANSIENT = (urllib.error.URLError, ConnectionError,
                  http.client.HTTPException, TimeoutError)

    def __init__(self, base_url: str, api_key: str,
                 namespace: str = "default", timeout_s: float = 60.0,
                 retry: Optional[RetryPolicy] = None):
        self.base_url = base_url.rstrip("/")
        self.api_key = api_key
        self.namespace = namespace
        self.timeout_s = timeout_s
        self.retry = retry or RetryPolicy()
        self.counters = {"requests": 0, "retries": 0}
        # server-side timing of the most recent op (the envelope's
        # queued_s/service_s/batch_size + the request id): remote callers
        # see where the time went, not just wall clock
        self.last_timing: dict = {}
        # injectable for deterministic tests (no real sleeping, seeded
        # jitter)
        self._sleep: Callable[[float], None] = time.sleep
        self._rng = random.Random()

    # -- transport ----------------------------------------------------------
    def _post_once(self, path: str, body: dict) -> dict:
        req = urllib.request.Request(
            self.base_url + path, data=json.dumps(body).encode(),
            headers={"Authorization": f"Bearer {self.api_key}",
                     "Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=self.timeout_s) as r:
                return json.loads(r.read().decode())
        except urllib.error.HTTPError as e:
            detail = {}
            try:
                detail = json.loads(e.read().decode())
            except Exception:
                pass
            if e.code == 429:
                raise AdmissionError(
                    detail.get("error", "rejected by admission control"),
                    reason=detail.get("reason", "overloaded"),
                    retry_after_s=float(detail.get("retry_after_s", 1.0)))
            err = RuntimeError(
                f"HTTP {e.code} from {path}: "
                f"{detail.get('error', e.reason)}")
            err.http_status = e.code
            raise err from None

    def _post(self, path: str, body: dict) -> dict:
        """_post_once under the retry policy.  Retries 429 (Retry-After
        honored), 5xx, and connection failures; every other failure — and
        the last attempt's — propagates unchanged."""
        pol = self.retry
        self.counters["requests"] += 1
        for attempt in range(pol.max_attempts):
            last = attempt == pol.max_attempts - 1
            try:
                return self._post_once(path, body)
            except AdmissionError as e:
                if last or not pol.retry_rate_limited:
                    raise
                delay = pol.backoff_s(attempt, self._rng,
                                      retry_after_s=e.retry_after_s)
            except self._TRANSIENT:
                if last:
                    raise
                delay = pol.backoff_s(attempt, self._rng)
            except RuntimeError as e:
                status = getattr(e, "http_status", None)
                if last or status is None or status < 500:
                    raise
                delay = pol.backoff_s(attempt, self._rng)
            self.counters["retries"] += 1
            if delay > 0:
                self._sleep(delay)
        raise AssertionError("unreachable")      # loop always returns/raises

    @staticmethod
    def _context_from_payload(payload) -> RetrievedContext:
        if not isinstance(payload, dict) \
                or payload.get("kind") != "retrieved_context":
            raise RuntimeError(f"unexpected retrieve payload: {payload!r}")
        return RetrievedContext(
            triples=[Triple(**t) for t in payload.get("triples", [])],
            summaries=[Summary(**s) for s in payload.get("summaries", [])],
            text=payload.get("text", ""),
            token_count=int(payload.get("token_count") or 0),
            degraded=bool(payload.get("degraded", False)))

    def _note_timing(self, env: dict) -> None:
        """Keep the envelope's server-side timing split (dropped on the
        floor before PR 9) where callers can read it back."""
        self.last_timing = {
            "queued_s": float(env.get("queued_s") or 0.0),
            "service_s": float(env.get("service_s") or 0.0),
            "batch_size": int(env.get("batch_size") or 1),
            "request_id": env.get("request_id"),
        }

    # -- MemoryLike ---------------------------------------------------------
    def retrieve(self, query: str, top_k=None) -> RetrievedContext:
        body = {"namespace": self.namespace, "query": query}
        if top_k is not None:
            body["top_k"] = top_k
        env = self._post("/v1/retrieve", body)
        if env.get("status") != "ok":
            raise RuntimeError(env.get("error") or "retrieve failed")
        self._note_timing(env)
        return self._context_from_payload(env.get("payload"))

    def retrieve_traced(self, query: str,
                        top_k=None) -> Tuple[RetrievedContext, dict]:
        """`retrieve` with `debug: true` — returns (context, span tree):
        the server-side trace of THIS request (frontend, admission, queue
        wait, scheduler tick, every executed plan stage), inline."""
        body = {"namespace": self.namespace, "query": query, "debug": True}
        if top_k is not None:
            body["top_k"] = top_k
        env = self._post("/v1/retrieve", body)
        if env.get("status") != "ok":
            raise RuntimeError(env.get("error") or "retrieve failed")
        self._note_timing(env)
        return (self._context_from_payload(env.get("payload")),
                env.get("trace") or {})

    def answer_prompt(self, question: str) -> Tuple[str, RetrievedContext]:
        ctx = self.retrieve(question)
        return ANSWER_PROMPT.format(memories=ctx.text,
                                    question=question), ctx

    def record_session(self, conversation_id: str, session_id: str,
                       messages) -> dict:
        env = self._post("/v1/record", {
            "namespace": self.namespace,
            "session_id": session_id,
            "conversation_id": conversation_id,
            "messages": [{"speaker": m.speaker, "text": m.text,
                          "timestamp": m.timestamp} for m in messages]})
        if env.get("status") != "ok":
            raise RuntimeError(env.get("error") or "record failed")
        self._note_timing(env)
        return env.get("payload") or {}

    def stats(self) -> dict:
        req = urllib.request.Request(
            self.base_url + "/v1/stats",
            headers={"Authorization": f"Bearer {self.api_key}"})
        with urllib.request.urlopen(req, timeout=self.timeout_s) as r:
            return json.loads(r.read().decode())


class MemoriClient:
    def __init__(self, llm_fn: Callable[[str], str], memory: MemoryLike,
                 user_name: str = "user", agent_name: str = "assistant"):
        self.llm = llm_fn
        self.memory = memory
        self.user_name = user_name
        self.agent_name = agent_name
        self._turn_buffer: list[Message] = []

    def chat(self, user_text: str, conversation_id: str = "default",
             timestamp: Optional[float] = None) -> str:
        ts = timestamp if timestamp is not None else time.time()
        prompt, ctx = self.memory.answer_prompt(user_text)
        reply = self.llm(prompt)
        self._turn_buffer.append(Message(self.user_name, user_text, ts))
        self._turn_buffer.append(Message(self.agent_name, reply, ts))
        return reply

    def end_session(self, conversation_id: str = "default",
                    session_id: Optional[str] = None) -> None:
        """Flush the buffered turns through Advanced Augmentation."""
        if not self._turn_buffer:
            return
        sid = session_id or f"s{next(_session_counter)}"
        self.memory.record_session(conversation_id, sid, self._turn_buffer)
        self._turn_buffer = []

    def context_tokens(self, user_text: str) -> int:
        """The Table-2 metric: tokens injected for this query."""
        return self.memory.retrieve(user_text).token_count

    def close(self) -> None:
        """Record any buffered turns, then shut the memory layer down
        cleanly if it is closable (a NamespaceView over a lifecycle-mounted
        MemoryService forwards to `service.close()`: final flush + snapshot
        rotation).  With the runtime's background flusher there is no need
        to call `end_session` in a loop — buffered sessions drain on their
        own; `close()` is the one call a well-behaved client owes on exit."""
        self.end_session()
        closer = getattr(self.memory, "close", None)
        if callable(closer):
            closer()

    def __enter__(self) -> "MemoriClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
