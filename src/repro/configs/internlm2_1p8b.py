"""internlm2-1.8b [dense] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544.  GQA [arXiv:2403.17297]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        arch_type="dense",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92544,
        source="[arXiv:2403.17297]",
        long_context_window=8192,
    )
