"""Memory-augmented agent serving: the full Memori stack end-to-end.

    PYTHONPATH=src python examples/agent_serve.py

A small LM is served with continuous batching behind the MemoriClient SDK,
fronted by the multi-tenant MemoryService: every user gets an isolated
namespace in one shared packed bank, chat turns retrieve structured memory
and record the exchange back through Advanced Augmentation, and the pending
queries of *all* tenants are answered in one batched retrieval (one embed
call + one namespace-masked topk_mips launch).  The service is mounted on
a lifecycle runtime: recorded sessions buffer in a bounded queue that a
background flusher drains in batched embed calls, every flush journals to
a write-ahead log in a durable directory, and `service.close()` (via the
SDK clients' `close()`) writes the final snapshot generation — restart the
process with the same directory and it recovers where it left off.  The LM
is random-init (this box trains ~minutes, not the hours a useful chat
model needs) — the demo shows the *system*: interception, retrieval,
isolation, token accounting, batched decode, durability — and, at the end,
the MemoryScheduler fusing independent concurrent clients' single retrieves into
one batched device launch per tick (continuous batching for memory ops).
"""
import tempfile
import threading
import time

import jax

from repro.configs import get_config
from repro.core import LifecyclePolicy, MemoriClient, MemoryService
from repro.core.embedder import HashEmbedder
from repro.data.tokenizer import HashTokenizer
from repro.models.model_api import Model
from repro.serving.engine import Engine
from repro.serving.sampler import SamplerConfig


def main():
    cfg = get_config("memori-agent").reduced(layers=2, d_model=128)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tok = HashTokenizer(cfg.vocab_size)
    engine = Engine(model, params, max_len=192, slots=2,
                    sampler=SamplerConfig(temperature=0.9, top_k=50),
                    tokenizer=tok)

    def llm(prompt: str) -> str:
        return engine.generate([prompt[-600:]], max_new_tokens=16)[0]

    data_dir = tempfile.mkdtemp(prefix="memori-agent-")
    service = MemoryService(
        HashEmbedder(), budget=800, use_kernel=False,
        data_dir=data_dir,
        policy=LifecyclePolicy(flush_interval_s=0.1, max_pending=128,
                               compact_tombstone_ratio=0.3,
                               snapshot_interval_s=10.0))
    users = {
        "priya/c0": ("Priya", [
            "Hi there! I am Priya.",
            "I work as a botanist and I live in Tallinn.",
            "My favorite color is indigo.",
            "I adopted a hedgehog named Biscuit.",
        ]),
        "marco/c0": ("Marco", [
            "Hello, Marco here.",
            "I work as a glassblower and I live in Porto.",
            "I adopted a parrot named Olive.",
        ]),
    }
    for ns, (name, turns) in users.items():
        client = MemoriClient(llm, service.namespace(ns), user_name=name)
        for t in turns:
            reply = client.chat(t, timestamp=time.time())
            print(f"{name}: {t}\n  agent: {reply[:60]}")
        # end_session enqueues into the runtime's bounded queue; the
        # background flusher drains it — no manual flush loop
        client.end_session()

    print("\nservice after sessions:", service.stats())
    # the cross-tenant hot path: both tenants' queries in ONE batched call
    # (reads are read-your-writes even while sessions sit in the queue)
    batch = [("priya/c0", "What is the name of Priya's pet?"),
             ("marco/c0", "What is the name of Marco's pet?")]
    for (ns, q), ctx in zip(batch, service.retrieve_batch(batch)):
        print(f"\n[{ns}] Q: {q}  ({ctx.token_count} tokens injected)")
        for t in ctx.triples[:3]:
            print(f"   {t.render()}")

    # cross-CLIENT batching: mount the MemoryScheduler and let independent
    # threads (each a client issuing ONE retrieve at a time, the real
    # deployment shape) coalesce into one device launch per tick — no
    # caller hand-assembles a batch
    service.start_scheduler(tick_interval_s=0.01, max_batch=16)
    answers = {}

    def client(ns, q):
        # service.retrieve routes through the scheduler automatically
        answers[ns] = service.retrieve(ns, q)

    threads = [threading.Thread(target=client, args=(ns, q))
               for ns, q in batch]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = service.scheduler.stats()
    print(f"\nscheduler: {st['retrieves']} concurrent single retrieves in "
          f"{st['retrieve_launches']} batched launch(es)")
    print(f"engine stats: {engine.stats}")
    service.close()          # scheduler drain + final flush + snapshot
    print(f"memory durable in {data_dir} "
          f"(MemoryService.recover picks it up)")


if __name__ == "__main__":
    main()
