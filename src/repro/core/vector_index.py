"""Sharded exact-MIPS vector index — the FAISS replacement (DESIGN.md §3).

Device-resident retrieval engine: the packed bank and the per-row effective
namespace labels (namespace id for live rows, -1 for tombstones and unfilled
capacity) live in capacity-doubling **device** buffers.  `add` / `delete`
update them in place (donated `dynamic_update_slice` / scatter — no
host round-trip), so steady-state search issues *zero* per-call bank H2D
transfers.  The number of live rows rides into the kernel as a traced SMEM
scalar and the jitted search is keyed only on the padded buffer shapes,
which change exclusively at power-of-two capacity boundaries — thousands of
appends reuse one executable.  A host mirror is kept for snapshot/compact
and as the plain-numpy source of truth (`bank`, `alive()`).

Single-device search runs the fused Pallas topk_mips kernel.  On a mesh, the
bank rows shard across every device (logical axis "bank"); search is the
classic distributed-ANN reduction expressed in shard_map:

    local top-k per shard  →  all_gather(k·shards candidates)  →  re-rank

and the namespace mask rides along shard-local, so one sharded launch serves
a whole batch of tenants (see `sharded_topk(..., q_ns=, bank_ns=)`).

Exact search is the right call *because of the paper*: Advanced Augmentation
compresses raw dialogue into triples, keeping the bank orders of magnitude
smaller than chunk-RAG banks — small enough that exact MIPS at full HBM
bandwidth beats approximate pointer-chasing structures on TPU.

**Quantized dual-buffer residency** (`quantize="int8"`): the f32 host
mirror stays the bit-exact ground truth (snapshots, WAL replay and
compaction read it and are unchanged), while the DEVICE buffers become an
int8 code bank plus per-row f32 scales — ~4x less HBM footprint and ~4x
less bank bandwidth per search, scanned by the fused dequant+MIPS kernel
(kernels/topk_mips.py, `scales=`).  Appends quantize the new rows on the
host (symmetric per-row: scale = max|row|/127) and ride the same donated
in-place pow2 update path, so the zero-recompile / zero-bank-upload steady
state is preserved.  Every search over-fetches `rescore`x the requested k
from the quantized bank, then an exact f32 **rescore** (one host gather of
the candidate rows from the mirror + one small batched matmul) re-ranks
the candidates, so the returned scores are exact and recall@k against the
f32 oracle stays >= 0.95 (asserted in tests and CI).

**Tiered residency** (`demote_rows` / `promote_rows`): a row can be
resident (searchable on device) or demoted (device slot zeroed/label -1,
full-precision truth still in the host mirror — the "warm" tier).  The
store/lifecycle TierManager (core/tiering.py) demotes cold namespaces'
rows and promotes them back in batched pow2 uploads; `search_host` is the
transparent host-side fallback for queries that hit a demoted namespace.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.utils import next_pow2 as _next_pow2
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels import topk_mips as _tm


# ---------------------------------------------------------------------------
# Device-side primitives.  All donate their buffer arguments so XLA updates
# the capacity-padded arrays in place (no realloc, no host round-trip); the
# jit cache is keyed on (capacity, update width) only, and callers pad the
# update width to a power of two (zero rows / -1 labels — exactly the
# unfilled-slot representation), so a lifecycle flusher draining a different
# number of sessions every interval still reuses a bounded executable set.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0, 1))
def _dev_append(bank, labels, vecs, ns, start):
    """Write `vecs` rows + `ns` labels at [start, start+m) in place."""
    bank = jax.lax.dynamic_update_slice(bank, vecs, (start, 0))
    labels = jax.lax.dynamic_update_slice(labels, ns, (start,))
    return bank, labels


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _dev_delete(bank, labels, ids):
    """Tombstone rows in place: zero the vectors, set the labels to -1."""
    bank = bank.at[ids].set(0.0)
    labels = labels.at[ids].set(-1)
    return bank, labels


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _dev_compact(bank, labels, gather, n_new):
    """Repack live rows in place: new row r takes old row `gather[r]` for
    r < n_new; the tail is zeroed / labeled -1.  Device-side compaction —
    no host round-trip, and the buffers keep their capacity, so the search
    executable (keyed on capacity) survives a compaction untouched."""
    live = jnp.arange(bank.shape[0]) < n_new
    bank = jnp.where(live[:, None], bank[gather], 0.0)
    labels = jnp.where(live, labels[gather], -1)
    return bank, labels


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _dev_restore(bank, labels, ids, vecs, ns):
    """Scatter rows + labels back into their slots (tier promotion: the
    demoted rows return from the host mirror).  Duplicate ids scatter the
    same values — pow2 id padding is idempotent."""
    bank = bank.at[ids].set(vecs)
    labels = labels.at[ids].set(ns)
    return bank, labels


# -- quantized variants: int8 code bank + (capacity,) f32 per-row scales ----

@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _dev_append_q(bank, scales, labels, vecs_i8, sc, ns, start):
    bank = jax.lax.dynamic_update_slice(bank, vecs_i8, (start, 0))
    scales = jax.lax.dynamic_update_slice(scales, sc, (start,))
    labels = jax.lax.dynamic_update_slice(labels, ns, (start,))
    return bank, scales, labels


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _dev_delete_q(bank, scales, labels, ids):
    bank = bank.at[ids].set(0)
    scales = scales.at[ids].set(0.0)
    labels = labels.at[ids].set(-1)
    return bank, scales, labels


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _dev_compact_q(bank, scales, labels, gather, n_new):
    live = jnp.arange(bank.shape[0]) < n_new
    bank = jnp.where(live[:, None], bank[gather], 0)
    scales = jnp.where(live, scales[gather], 0.0)
    labels = jnp.where(live, labels[gather], -1)
    return bank, scales, labels


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _dev_restore_q(bank, scales, labels, ids, vecs_i8, sc, ns):
    bank = bank.at[ids].set(vecs_i8)
    scales = scales.at[ids].set(sc)
    labels = labels.at[ids].set(ns)
    return bank, scales, labels


def quantize_rows_np(vecs: np.ndarray):
    """Symmetric per-row int8 quantization on the host (append/promote-time;
    rows are few, the bank-wide pass happens once per materialization).
    Matches `kernels/ref.quantize_rows_ref` bit-exactly: scale =
    max|row|/127, codes = round-half-even(row/scale) in [-127, 127]; an
    all-zero row keeps scale 0 and zero codes."""
    vecs = np.asarray(vecs, np.float32)
    amax = np.max(np.abs(vecs), axis=1) if vecs.size else \
        np.zeros((vecs.shape[0],), np.float32)
    scale = (amax / np.float32(127.0)).astype(np.float32)
    inv = np.where(scale > 0, np.float32(1.0) /
                   np.where(scale > 0, scale, 1), 0).astype(np.float32)
    codes = np.clip(np.rint(vecs * inv[:, None]), -127, 127).astype(np.int8)
    return codes, scale


@functools.partial(jax.jit,
                   static_argnames=("k", "use_kernel", "interpret", "uniform"))
def _search_device(bank, labels, queries, q_ns, n_valid, *, k: int,
                   use_kernel: bool, interpret: bool, uniform: bool):
    """The stable-shape jitted hot path: one masked top-k over the padded
    device bank.  `n_valid` is traced — appends within a capacity bucket
    reuse this executable.  With `uniform=True` the namespace structure is
    collapsed (any live row matches: the single-tenant / tombstone-only
    search).  Empty slots come back as (-inf, -1)."""
    bank_ns = jnp.where(labels >= 0, 0, -1) if uniform else labels
    if use_kernel:
        s, i = _tm.topk_mips(queries, bank, k, n_valid=n_valid, q_ns=q_ns,
                             bank_ns=bank_ns, interpret=interpret)
    else:
        s, i = kref.topk_mips_masked_ref(queries, bank, q_ns, bank_ns, k=k,
                                         n_valid=n_valid)
    return jnp.where(i >= 0, s, -jnp.inf), i


@functools.partial(jax.jit,
                   static_argnames=("k", "use_kernel", "interpret", "uniform"))
def _search_device_quant(bank_i8, scales, labels, queries, q_ns, n_valid, *,
                         k: int, use_kernel: bool, interpret: bool,
                         uniform: bool):
    """Quantized twin of `_search_device`: one fused dequant+MIPS launch
    over the int8 code bank (the bank scan reads 1 byte/element).  Same
    traced-`n_valid` stable-shape contract; empty slots are (-inf, -1)."""
    bank_ns = jnp.where(labels >= 0, 0, -1) if uniform else labels
    if use_kernel:
        s, i = _tm.topk_mips(queries, bank_i8, k, n_valid=n_valid, q_ns=q_ns,
                             bank_ns=bank_ns, scales=scales,
                             interpret=interpret)
    else:
        s, i = kref.topk_mips_quant_masked_ref(queries, bank_i8, scales,
                                               q_ns, bank_ns, k=k,
                                               n_valid=n_valid)
    return jnp.where(i >= 0, s, -jnp.inf), i


@functools.partial(jax.jit, static_argnames=("k",))
def _rescore_exact(queries, cand_rows, cand_ids, *, k: int):
    """Exact f32 re-rank of the quantized candidates: `cand_rows`
    (Q, C, D) are the candidates' FULL-PRECISION rows gathered from the
    host mirror (the ground truth), `cand_ids` (Q, C) their bank ids (-1 =
    empty slot).  One small batched matmul; returns the top-k by exact
    score, (-inf, -1) padded — so the scores leaving a quantized index are
    exact, and quantization error only costs recall when a true top-k row
    falls outside the C-candidate pool."""
    s = jnp.einsum("qd,qcd->qc", queries, cand_rows)
    s = jnp.where(cand_ids >= 0, s, _tm.NEG_INF)
    top_s, pos = jax.lax.top_k(s, k)
    top_i = jnp.take_along_axis(cand_ids, pos, axis=1)
    top_i = jnp.where(top_s > _tm.NEG_INF / 2, top_i, -1)
    return jnp.where(top_i >= 0, top_s, -jnp.inf), top_i


def _next_capacity(n: int, floor: int = 64) -> int:
    return max(floor, _next_pow2(n))


class VectorIndex:
    def __init__(self, dim: int, capacity: int = 1024, use_kernel: bool = True,
                 quantize: str = "none", rescore: int = 4):
        if quantize not in ("none", "int8"):
            raise ValueError(f"quantize {quantize!r} must be 'none' or "
                             "'int8'")
        if rescore < 1:
            raise ValueError("rescore must be >= 1")
        self.dim = dim
        self.n = 0
        self._n_dead = 0                 # O(1) tombstone counter
        self.use_kernel = use_kernel
        self.quantize = quantize
        self.rescore = rescore           # candidate over-fetch multiplier
        capacity = _next_capacity(capacity)
        # host mirror: source of truth for snapshot/compact and numpy readers
        self._bank = np.zeros((capacity, dim), np.float32)
        self._alive = np.ones((capacity,), bool)
        self._ns = np.zeros((capacity,), np.int32)   # raw per-row labels
        # tier residency: False = demoted (device slot dead, host truth
        # intact — the warm tier).  Searches only see resident rows.
        self._resident = np.ones((capacity,), bool)
        # device buffers (lazily materialized, then incrementally updated);
        # quantized mode keeps (capacity, dim) int8 codes + (capacity,) f32
        # scales instead of the (capacity, dim) f32 bank
        self._bank_dev = None
        self._labels_dev = None
        self._scales_dev = None
        # quantized-search observability: rescore_hits / rescore_rows is
        # the fraction of final top-k ids the quantized ordering already
        # had in ITS top-k (how often the rescore merely re-scores rather
        # than re-ranks) — exported as the "rescore hit rate" gauge
        self.counters = {"quant_searches": 0, "rescore_rows": 0,
                         "rescore_hits": 0}

    # -- device residency ---------------------------------------------------
    @property
    def capacity(self) -> int:
        return self._bank.shape[0]

    def _effective_labels(self) -> np.ndarray:
        """(capacity,) i32: ns label for live rows in [0, n), else -1."""
        eff = np.full((self.capacity,), -1, np.int32)
        eff[: self.n] = np.where(self._alive[: self.n], self._ns[: self.n], -1)
        return eff

    def _invalidate_device(self) -> None:
        self._bank_dev = None
        self._labels_dev = None
        self._scales_dev = None

    def _ensure_device(self) -> None:
        """Materialize the device buffers from the host mirror.  Happens on
        the first search and after capacity changes (grow/compact/load) —
        never on the steady-state search path.  Demoted rows materialize
        with a -1 label (device-dead); quantized mode uploads int8 codes +
        scales instead of the f32 bank (~4x fewer bytes)."""
        if self._bank_dev is None:
            eff = np.where(self._resident, self._effective_labels(), -1)
            if self.quantize == "none":
                self._bank_dev = jnp.asarray(self._bank)
            else:
                codes, scales = quantize_rows_np(self._bank)
                self._bank_dev = jnp.asarray(codes)
                self._scales_dev = jnp.asarray(scales)
            self._labels_dev = jnp.asarray(eff)

    def row_labels_device(self):
        """(capacity,) i32 device array of effective namespace labels (live
        row -> its ns id, tombstone/unfilled/demoted -> -1).  Cached
        device-side and updated in place by add/delete; invalidated by
        compact/load_rows.  Returns the LIVE cached buffer — zero per-call
        device allocations (asserted in tests).  Callers must treat it as
        read-only and must not hold it across writes: the next add/delete
        donates (and on backends honoring donation, deletes) it."""
        self._ensure_device()
        return self._labels_dev

    # -- writes --------------------------------------------------------------
    def add(self, vecs, ns=None) -> np.ndarray:
        """Append rows.  `ns` labels the new rows' namespace (scalar or
        per-row sequence; default 0).  The device buffers are updated in
        place unless the append crosses a capacity boundary."""
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        m = vecs.shape[0]
        if np.ndim(ns) == 0:
            ns_rows = np.full((m,), 0 if ns is None else int(ns), np.int32)
        else:
            ns_rows = np.asarray(ns, np.int32)
            if ns_rows.shape != (m,):
                raise ValueError(
                    f"{ns_rows.shape[0]} namespace labels for {m} rows")
        if self.n + m > self.capacity:
            cap = _next_capacity(self.n + m, floor=2 * self.capacity)
            bank = np.zeros((cap, self.dim), np.float32)
            bank[: self.n] = self._bank[: self.n]
            alive = np.ones((cap,), bool)
            alive[: self.n] = self._alive[: self.n]
            labels = np.zeros((cap,), np.int32)
            labels[: self.n] = self._ns[: self.n]
            resident = np.ones((cap,), bool)
            resident[: self.n] = self._resident[: self.n]
            self._bank, self._alive, self._ns = bank, alive, labels
            self._resident = resident
            self._invalidate_device()     # re-upload once per doubling
        ids = np.arange(self.n, self.n + m)
        self._bank[self.n: self.n + m] = vecs
        self._alive[self.n: self.n + m] = True
        self._ns[self.n: self.n + m] = ns_rows
        self._resident[self.n: self.n + m] = True
        if self._bank_dev is not None:
            # pad the update width to the next power of two (bounded by the
            # remaining capacity) so variable-size flush batches reuse a
            # bounded set of append executables; pad rows are written as
            # zero vectors with -1 labels — the unfilled-slot representation
            # those slots already hold
            m_pad = max(m, min(_next_pow2(m), self.capacity - self.n))
            vec_up, ns_up = vecs, ns_rows
            if m_pad > m:
                vec_up = np.zeros((m_pad, self.dim), np.float32)
                vec_up[:m] = vecs
                ns_up = np.full((m_pad,), -1, np.int32)
                ns_up[:m] = ns_rows
            if self.quantize == "none":
                self._bank_dev, self._labels_dev = _dev_append(
                    self._bank_dev, self._labels_dev, jnp.asarray(vec_up),
                    jnp.asarray(ns_up), jnp.int32(self.n))
            else:
                # quantize the (few) new rows on the host; the bank-wide
                # int8 buffer is only ever touched in place
                codes, scales = quantize_rows_np(vec_up)
                self._bank_dev, self._scales_dev, self._labels_dev = \
                    _dev_append_q(self._bank_dev, self._scales_dev,
                                  self._labels_dev, jnp.asarray(codes),
                                  jnp.asarray(scales), jnp.asarray(ns_up),
                                  jnp.int32(self.n))
        self.n += m
        return ids

    @property
    def bank(self) -> np.ndarray:
        return self._bank[: self.n]

    @property
    def n_alive(self) -> int:
        return self.n - self._n_dead

    @property
    def n_dead(self) -> int:
        """Tombstone count, O(1) — cheap enough for the lifecycle daemon to
        poll every tick."""
        return self._n_dead

    def alive(self, ids=None):
        """Liveness of `ids` (or the full (n,) mask when ids is None)."""
        if ids is None:
            return self._alive[: self.n].copy()
        return self._alive[np.asarray(ids, np.int64)]

    def row_namespaces(self) -> np.ndarray:
        """(n,) i32 raw namespace labels (host mirror; tombstones keep their
        retired label here — the *effective* device labels mask them)."""
        return self._ns[: self.n].copy()

    def delete(self, ids) -> int:
        """Tombstone rows: ids keep their slots (the tid==row alignment with
        TripleStore/BM25 survives) but the vectors are physically zeroed and
        the rows never surface from search again.  Returns #newly deleted."""
        ids = np.asarray(ids, np.int64).ravel()
        ids = ids[(ids >= 0) & (ids < self.n)]
        ids = ids[self._alive[ids]]
        self._alive[ids] = False
        self._bank[ids] = 0.0
        self._n_dead += int(ids.size)
        if ids.size and self._bank_dev is not None:
            # pad the id width to a power of two (duplicate scatter of the
            # last id is idempotent) — bounded executable count under
            # variable-size evictions
            pad = _next_pow2(int(ids.size))
            ids_up = ids if pad == ids.size else np.concatenate(
                [ids, np.full((pad - ids.size,), ids[-1], np.int64)])
            if self.quantize == "none":
                self._bank_dev, self._labels_dev = _dev_delete(
                    self._bank_dev, self._labels_dev, jnp.asarray(ids_up))
            else:
                self._bank_dev, self._scales_dev, self._labels_dev = \
                    _dev_delete_q(self._bank_dev, self._scales_dev,
                                  self._labels_dev, jnp.asarray(ids_up))
        return int(ids.size)

    def compact(self) -> np.ndarray:
        """Physically drop tombstoned rows, repacking the bank.  Returns the
        old→new row id mapping as an (n_old,) int64 array (-1 for dropped
        rows); kept rows keep their relative order.  Callers owning
        row-aligned side tables (see core/store.py) must remap them with the
        returned array.

        Capacity is sticky: the buffers are NOT shrunk, and the device
        copies are repacked in place by a donated gather (`_dev_compact`) —
        a compaction moves zero bank bytes host->device and leaves the
        search executable (keyed on capacity) untouched."""
        n_old = self.n
        alive = self._alive[:n_old]
        old_to_new = np.full((n_old,), -1, np.int64)
        keep = np.where(alive)[0]
        old_to_new[keep] = np.arange(keep.size)
        n_new = int(keep.size)
        cap = self.capacity
        bank = np.zeros((cap, self.dim), np.float32)
        bank[:n_new] = self._bank[keep]
        labels = np.zeros((cap,), np.int32)
        labels[:n_new] = self._ns[keep]
        resident = np.ones((cap,), bool)
        resident[:n_new] = self._resident[keep]     # demoted rows stay warm
        self._bank = bank
        self._alive = np.ones((cap,), bool)
        self._ns = labels
        self._resident = resident
        self.n = n_new
        self._n_dead = 0
        if self._bank_dev is not None:
            gather = np.zeros((cap,), np.int32)
            gather[:n_new] = keep
            # the device gather carries demoted slots along as they are
            # (zeroed codes, -1 labels) — tier state survives a compaction
            if self.quantize == "none":
                self._bank_dev, self._labels_dev = _dev_compact(
                    self._bank_dev, self._labels_dev, jnp.asarray(gather),
                    jnp.int32(n_new))
            else:
                self._bank_dev, self._scales_dev, self._labels_dev = \
                    _dev_compact_q(self._bank_dev, self._scales_dev,
                                   self._labels_dev, jnp.asarray(gather),
                                   jnp.int32(n_new))
        return old_to_new

    def load_rows(self, bank, alive, ns=None) -> None:
        """Bulk-load a snapshot's rows (replaces any current content).
        `ns` carries the per-row namespace labels (default 0)."""
        bank = np.asarray(bank, np.float32)
        n = bank.shape[0]
        if bank.ndim != 2 or bank.shape[1] != self.dim:
            raise ValueError(f"bank shape {bank.shape} != (*, {self.dim})")
        cap = _next_capacity(n)
        self._bank = np.zeros((cap, self.dim), np.float32)
        self._bank[:n] = bank
        self._alive = np.ones((cap,), bool)
        self._alive[:n] = np.asarray(alive, bool)
        self._ns = np.zeros((cap,), np.int32)
        if ns is not None:
            self._ns[:n] = np.asarray(ns, np.int32)
        self._resident = np.ones((cap,), bool)   # a fresh load is all-hot
        self.n = n
        self._n_dead = n - int(self._alive[:n].sum())
        self._invalidate_device()

    # -- tiered residency (hot device rows / warm host rows) ------------------
    @property
    def n_resident(self) -> int:
        """Live rows currently searchable on device (the hot tier)."""
        m = self.n
        return int((self._alive[:m] & self._resident[:m]).sum())

    @property
    def n_warm(self) -> int:
        """Live rows demoted to the host mirror (the warm tier)."""
        m = self.n
        return int((self._alive[:m] & ~self._resident[:m]).sum())

    def resident_mask(self) -> np.ndarray:
        """(n,) bool: True where the row is device-resident."""
        return self._resident[: self.n].copy()

    def rows_in_namespace(self, ns_id: int) -> np.ndarray:
        """Live global row ids labeled `ns_id` (host mirror scan)."""
        m = self.n
        return np.where(self._alive[:m] & (self._ns[:m] == ns_id))[0]

    def demote_rows(self, ids) -> int:
        """Move rows to the warm tier: their DEVICE slots are zeroed and
        label -1 (they stop matching any query), while the host mirror — the
        full-precision ground truth — is untouched, so snapshots, WAL
        replay, compaction and `promote_rows` all still see them.  In-place
        donated scatter, pow2-padded: no recompile churn, no bank upload.
        Returns #rows newly demoted."""
        ids = np.asarray(ids, np.int64).ravel()
        ids = ids[(ids >= 0) & (ids < self.n)]
        ids = ids[self._resident[ids]]
        if not ids.size:
            return 0
        self._resident[ids] = False
        if self._bank_dev is not None:
            pad = _next_pow2(int(ids.size))
            ids_up = ids if pad == ids.size else np.concatenate(
                [ids, np.full((pad - ids.size,), ids[-1], np.int64)])
            if self.quantize == "none":
                self._bank_dev, self._labels_dev = _dev_delete(
                    self._bank_dev, self._labels_dev, jnp.asarray(ids_up))
            else:
                self._bank_dev, self._scales_dev, self._labels_dev = \
                    _dev_delete_q(self._bank_dev, self._scales_dev,
                                  self._labels_dev, jnp.asarray(ids_up))
        return int(ids.size)

    def promote_rows(self, ids) -> int:
        """Bring warm rows back to the device: one batched pow2-padded
        in-place scatter of the rows (quantized on the host first in int8
        mode) plus their effective labels, from the host mirror.  Returns
        #rows promoted."""
        ids = np.asarray(ids, np.int64).ravel()
        ids = ids[(ids >= 0) & (ids < self.n)]
        ids = ids[~self._resident[ids]]
        if not ids.size:
            return 0
        self._resident[ids] = True
        if self._bank_dev is not None:
            pad = _next_pow2(int(ids.size))
            ids_up = ids if pad == ids.size else np.concatenate(
                [ids, np.full((pad - ids.size,), ids[-1], np.int64)])
            vecs = self._bank[ids_up]
            # tombstoned-while-warm rows come back as device tombstones
            ns_up = np.where(self._alive[ids_up], self._ns[ids_up],
                             -1).astype(np.int32)
            if self.quantize == "none":
                self._bank_dev, self._labels_dev = _dev_restore(
                    self._bank_dev, self._labels_dev, jnp.asarray(ids_up),
                    jnp.asarray(vecs), jnp.asarray(ns_up))
            else:
                codes, scales = quantize_rows_np(vecs)
                self._bank_dev, self._scales_dev, self._labels_dev = \
                    _dev_restore_q(self._bank_dev, self._scales_dev,
                                   self._labels_dev, jnp.asarray(ids_up),
                                   jnp.asarray(codes), jnp.asarray(scales),
                                   jnp.asarray(ns_up))
        return int(ids.size)

    def search_host(self, queries, q_ns, k: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side masked exact search over the FULL host mirror (hot and
        warm rows alike) — the transparent fallback for queries whose
        namespace is demoted from the device bank.  Pure numpy: exact f32
        scores, same (-inf, -1) fill contract as the device searches."""
        queries = np.asarray(queries, np.float32)
        if queries.ndim == 1:
            queries = queries[None]
        Q = queries.shape[0]
        if self.n == 0 or self.n_alive == 0:
            return self._empty(Q, k)
        m = self.n
        eff = np.where(self._alive[:m], self._ns[:m], -1)
        s = queries @ self._bank[:m].T                      # (Q, n)
        ok = np.asarray(q_ns, np.int32)[:, None] == eff[None, :]
        s = np.where(ok, s, -np.inf)
        kk = min(k, m)
        part = np.argpartition(-s, kk - 1, axis=1)[:, :kk]
        ps = np.take_along_axis(s, part, axis=1)
        order = np.argsort(-ps, axis=1, kind="stable")
        idx = np.take_along_axis(part, order, axis=1).astype(np.int64)
        scs = np.take_along_axis(ps, order, axis=1).astype(np.float32)
        idx = np.where(np.isfinite(scs), idx, -1)
        if kk < k:
            scs = np.pad(scs, ((0, 0), (0, k - kk)),
                         constant_values=-np.inf)
            idx = np.pad(idx, ((0, 0), (0, k - kk)), constant_values=-1)
        return scs, idx

    # -- reads ---------------------------------------------------------------
    def _empty(self, Q: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
        return (np.full((Q, k), -np.inf, np.float32),
                np.full((Q, k), -1, np.int64))

    def _run_search(self, queries, q_ns, k: int, labels=None,
                    uniform: bool = False):
        """Shared driver for every search flavor: clamp k to the padded
        capacity, run the stable-shape jitted search, hand back device
        arrays.  `labels=None` uses the cached device labels.

        Quantized mode over-fetches `rescore`x k candidates from the int8
        bank (candidate count bucketed to pow2 — one executable per (Q, k)
        bucket), then re-ranks them by exact f32 score: one host gather of
        the candidate rows from the mirror + one small batched matmul
        (`_rescore_exact`).  The gather moves Q*C*D*4 bytes — candidates,
        never the bank."""
        self._ensure_device()
        if labels is None:
            labels = self._labels_dev
        kk = min(k, self.capacity)
        if self.quantize == "none":
            s, i = _search_device(
                self._bank_dev, labels, queries, q_ns, jnp.int32(self.n),
                k=kk, use_kernel=self.use_kernel,
                interpret=kops._interpret_default(), uniform=uniform)
            return s, i, kk
        kc = min(self.capacity, _next_pow2(kk * self.rescore))
        s, i = _search_device_quant(
            self._bank_dev, self._scales_dev, labels, queries, q_ns,
            jnp.int32(self.n), k=kc, use_kernel=self.use_kernel,
            interpret=kops._interpret_default(), uniform=uniform)
        i_host = np.asarray(i)                       # (Q, C) candidate ids
        cand = self._bank[np.clip(i_host, 0, self.capacity - 1)]
        s, i = _rescore_exact(queries, jnp.asarray(cand),
                              jnp.asarray(i_host), k=kk)
        self.counters["quant_searches"] += 1
        i_np = np.asarray(i)                         # small (Q, k) D2H
        firstk = i_host[:, :kk]
        for r in range(i_np.shape[0]):
            fin = i_np[r][i_np[r] >= 0]
            self.counters["rescore_rows"] += int(fin.size)
            self.counters["rescore_hits"] += int(np.isin(fin,
                                                         firstk[r]).sum())
        return s, i, kk

    def _to_host(self, s, i, k: int, kk: int):
        s = np.asarray(s)
        i = np.asarray(i, np.int64)
        if kk < k:
            s = np.pad(s, ((0, 0), (0, k - kk)), constant_values=-np.inf)
            i = np.pad(i, ((0, 0), (0, k - kk)), constant_values=-1)
        return s, i

    def search(self, queries, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """queries (Q, D) -> (scores (Q, k), ids (Q, k)); empty slots (rows
        beyond n, tombstones crowding out candidates) are (-inf, -1).  Runs
        the namespace-collapsed masked search over the device-resident bank:
        k stays static across add()/delete() — no retrace, no over-fetch."""
        queries = jnp.asarray(queries, jnp.float32)
        if queries.ndim == 1:
            queries = queries[None]
        Q = queries.shape[0]
        if self.n == 0 or self.n_alive == 0:
            return self._empty(Q, k)
        s, i, kk = self._run_search(
            queries, jnp.zeros((Q,), jnp.int32), k, uniform=True)
        return self._to_host(s, i, k, kk)

    def search_batch(self, queries, q_ns, k: int):
        """The multi-tenant hot path: one stable-shape launch over the
        device-resident bank using the *cached* device labels (no per-call
        label rebuild, no bank transfer).  Returns DEVICE arrays
        (scores (Q, k) f32, ids (Q, k) i32) so callers can keep fusing
        on-device; empty slots are (-inf, -1)."""
        queries = jnp.asarray(queries, jnp.float32)
        if queries.ndim == 1:
            queries = queries[None]
        Q = queries.shape[0]
        if self.n == 0 or self.n_alive == 0:
            return (jnp.full((Q, k), -jnp.inf, jnp.float32),
                    jnp.full((Q, k), -1, jnp.int32))
        q_ns = jnp.asarray(q_ns, jnp.int32)
        s, i, kk = self._run_search(queries, q_ns, k)
        if kk < k:
            s = jnp.pad(s, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
            i = jnp.pad(i, ((0, 0), (0, k - kk)), constant_values=-1)
        return s, i

    def search_masked(self, queries, q_ns, row_ns, k: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched multi-tenant search with *caller-supplied* labels:
        q_ns (Q,) >= 0 is each query's namespace, row_ns (n,) labels every
        bank row; tombstoned rows are masked regardless of their label.
        The bank itself stays device-resident; only the (n,) label vector is
        uploaded.  Prefer `search_batch` (cached labels) on the hot path."""
        queries = jnp.asarray(queries, jnp.float32)
        if queries.ndim == 1:
            queries = queries[None]
        Q = queries.shape[0]
        if self.n == 0 or self.n_alive == 0:
            return self._empty(Q, k)
        row_ns = np.asarray(row_ns, np.int32)
        if row_ns.shape != (self.n,):
            raise ValueError(f"row_ns shape {row_ns.shape} != ({self.n},)")
        eff = np.full((self.capacity,), -1, np.int32)
        ok = self._alive[: self.n] & self._resident[: self.n]
        eff[: self.n] = np.where(ok, row_ns, -1)
        s, i, kk = self._run_search(queries, jnp.asarray(q_ns, jnp.int32), k,
                                    labels=jnp.asarray(eff))
        return self._to_host(s, i, k, kk)


# ---------------------------------------------------------------------------
# Distributed search (shard_map): used by launch/dryrun and on real meshes.
# ---------------------------------------------------------------------------

# jax moved shard_map out of experimental (and renamed check_rep->check_vma);
# support both so the CPU-mesh parity tests run on older pinned jax too
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:                                    # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def _shard_map_unchecked(fn, mesh, in_specs, out_specs):
    import inspect
    flag = "check_vma" if "check_vma" in \
        inspect.signature(_shard_map).parameters else "check_rep"
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{flag: False})


def sharded_topk(queries, bank, k: int, mesh: Mesh, axis_names=("data", "model"),
                 *, q_ns=None, bank_ns=None, use_kernel: bool = True,
                 interpret: Optional[bool] = None):
    """bank rows sharded over `axis_names` (flattened); returns global
    (scores (Q,k), ids (Q,k)).  Local top-k → all_gather → re-rank.

    Local shard scoring runs the fused Pallas kernel (interpret mode
    off-TPU); pass `use_kernel=False` for the pure-jnp oracle path.

    Namespace-masked sharded search: pass q_ns (Q,) i32 and bank_ns (N,)
    i32 (both or neither; bank_ns shards with the bank rows, -1 marks
    tombstones).  Cross-namespace rows never surface — results match the
    single-device masked search exactly (ids -1 / scores NEG_INF for
    unfilled slots), including when a tenant owns fewer than k rows or
    k exceeds the per-shard row count."""
    flat_axes = tuple(a for a in axis_names if a in mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in flat_axes]))
    N = bank.shape[0]
    assert N % n_shards == 0, (N, n_shards)
    shard_rows = N // n_shards
    masked = q_ns is not None or bank_ns is not None
    if masked:
        assert q_ns is not None and bank_ns is not None, \
            "q_ns and bank_ns must be given together"
        q_ns = jnp.asarray(q_ns, jnp.int32)
        bank_ns = jnp.asarray(bank_ns, jnp.int32)
    interp = kops._interpret_default() if interpret is None else interpret
    k_local = min(k, shard_rows)

    def _rerank(s, i):
        # gather candidates from every shard, then re-rank globally
        s_all = jax.lax.all_gather(s, flat_axes, axis=1, tiled=True)
        i_all = jax.lax.all_gather(i, flat_axes, axis=1, tiled=True)
        top_s, pos = jax.lax.top_k(s_all, k)
        top_i = jnp.take_along_axis(i_all, pos, axis=1)
        return top_s, top_i

    def local(q, b):
        # positional index of this shard along the flattened bank axes
        idx = jax.lax.axis_index(flat_axes)
        if use_kernel:
            s, i = _tm.topk_mips(q, b, k_local, interpret=interp)
        else:
            s, i = kref.topk_mips_ref(q, b, k=k_local)
        i = i + idx * shard_rows
        return _rerank(s, i)

    def local_masked(q, b, qns, bns):
        idx = jax.lax.axis_index(flat_axes)
        if use_kernel:
            s, i = _tm.topk_mips(q, b, k_local, q_ns=qns, bank_ns=bns,
                                 interpret=interp)
        else:
            s, i = kref.topk_mips_masked_ref(q, b, qns, bns, k=k_local)
        # -1 sentinels (masked-out slots) must not be offset into real ids
        i = jnp.where(i >= 0, i + idx * shard_rows, -1)
        top_s, top_i = _rerank(s, i)
        return top_s, jnp.where(top_s > _tm.NEG_INF / 2, top_i, -1)

    spec_bank = P(flat_axes)
    # outputs are replicated by construction (all_gather + local re-rank);
    # the replication checker can't prove it, so we assert it ourselves
    if masked:
        fn = _shard_map_unchecked(local_masked, mesh=mesh,
                                  in_specs=(P(), spec_bank, P(), spec_bank),
                                  out_specs=(P(), P()))
        return fn(queries, bank, q_ns, bank_ns)
    fn = _shard_map_unchecked(local, mesh=mesh,
                              in_specs=(P(), spec_bank),
                              out_specs=(P(), P()))
    return fn(queries, bank)
