"""Advanced Augmentation — the paper's memory-creation pipeline (§2.1).

Distills raw dialogue sessions into the dual-layer memory asset:
semantic triples (precise, token-efficient facts, embedded + BM25-indexed)
and conversation summaries (narrative context), with triples linked to the
summary of the session they came from.

Designed as a *background* pipeline: `enqueue` is cheap; `process_pending`
runs extraction/embedding/indexing in batches (in production this is the
async worker; the benchmark calls it synchronously).

Since the storage-engine refactor this is a thin single-tenant wrapper over
`core/store.py`'s MemoryStore — the same write path MemoryService batches
across tenants.  All sessions (any number of conversations) land in one
internal namespace, which keeps the historical alignment triple id ==
bank row == BM25 doc id that `MemoriMemory`'s hybrid search relies on.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.extraction import Extractor, Message
from repro.core.store import MemoryStore
from repro.core.summaries import Summary
from repro.core.triples import Triple


class AdvancedAugmentation:
    _NS = "__single__"

    def __init__(self, embedder, extractor: Optional[Extractor] = None,
                 dim: int = 256, use_kernel: bool = True):
        self.store = MemoryStore(embedder, extractor, dim=dim,
                                 use_kernel=use_kernel)
        self.embedder = embedder
        self.extractor = self.store.extractor

    # the single tenant's stores, exposed under the historical names
    @property
    def triples(self):
        return self.store.tenant(self._NS).triples

    @property
    def summaries(self):
        return self.store.tenant(self._NS).summaries

    @property
    def vindex(self):
        return self.store.vindex

    @property
    def bm25(self):
        return self.store.bm25

    # -- background pipeline surface ------------------------------------
    def enqueue(self, conversation_id: str, session_id: str,
                messages: Sequence[Message]) -> None:
        self.store.enqueue(self._NS, session_id, messages,
                           conversation_id=conversation_id)

    def process_pending(self) -> int:
        """Batched drain: one embed_texts call + one bank append for every
        pending session (see MemoryStore.flush)."""
        return len(self.store.flush())

    def ingest(self, conversation_id: str, session_id: str,
               messages: Sequence[Message]) -> Tuple[List[Triple], Summary]:
        """Synchronous enqueue+process of one session."""
        return self.store.ingest(self._NS, session_id, messages,
                                 conversation_id=conversation_id)

    # -- stats -------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "triples": len(self.triples),
            "summaries": len(self.summaries),
            "bank_rows": self.vindex.n,
            "pending": self.store.pending_count,
        }
