"""LM training data pipeline: synthetic conversational text -> token batches.

Source text is the same generator family as the benchmark (multi-session
dialogues), giving the 100M-model example a learnable distribution.  The
pipeline is an infinite, deterministic iterator producing {tokens,
loss_mask} dicts of shape (batch, seq_len) — with optional stacked
microbatches for grad accumulation.
"""
from __future__ import annotations

import itertools
from typing import Dict, Iterator

import jax.numpy as jnp
import numpy as np

from repro.data.locomo_synth import generate_conversation
from repro.data.tokenizer import BOS_ID, EOS_ID, HashTokenizer, default_tokenizer


def token_stream(tokenizer: HashTokenizer, seed: int = 0) -> Iterator[int]:
    for conv_seed in itertools.count(seed * 1000):
        conv = generate_conversation(seed=conv_seed, n_sessions=4,
                                     noise_turns=40)
        for _, msgs in conv.sessions:
            for m in msgs:
                yield BOS_ID
                yield from tokenizer.encode(f"{m.speaker}: {m.text}")
                yield EOS_ID


def batches(batch_size: int, seq_len: int, *, tokenizer=None, seed: int = 0,
            microbatches: int = 0, vocab_size: int = 0) -> Iterator[Dict]:
    """Infinite iterator of {tokens (B,S) int32, loss_mask (B,S) f32}.
    With microbatches>0 shapes become (M, B, S) for lax.scan accumulation.
    Pass vocab_size to build a tokenizer matched to the model's vocab."""
    tok = tokenizer or (HashTokenizer(vocab_size) if vocab_size
                        else default_tokenizer())
    stream = token_stream(tok, seed)
    eff = batch_size * max(1, microbatches)
    while True:
        buf = np.fromiter(itertools.islice(stream, eff * seq_len),
                          np.int32, count=eff * seq_len)
        tokens = buf.reshape(eff, seq_len)
        mask = (tokens != 0).astype(np.float32)
        if microbatches:
            tokens = tokens.reshape(microbatches, batch_size, seq_len)
            mask = mask.reshape(microbatches, batch_size, seq_len)
        yield {"tokens": jnp.asarray(tokens), "loss_mask": jnp.asarray(mask)}
