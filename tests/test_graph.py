"""Device-resident memory graph (core/graph.py) and its RetrievalPlan
stage: batched k-hop expansion vs the scalar BFS oracle (exact ids, order
and float32 scores) under interleaved mutation, zero-recompile/zero-upload
steady state, namespace isolation, durability (snapshot/restore + WAL
replay bit-identity) and the store alignment invariants."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import io as ckpt_io
from repro.common.utils import count_compiles
from repro.core import graph as graph_mod
from repro.core.api import RetrievalPlan, RetrieveRequest
from repro.core.embedder import HashEmbedder
from repro.core.extraction import Message
from repro.core.graph import (EDGE_CAUSAL, EDGE_ENTITY, EDGE_TEMPORAL,
                              GraphInvariantError, MemoryGraph)
from repro.core.service import MemoryService
from repro.core.store import MemoryStore, StoreInvariantError
from repro.core.triples import Triple, TripleStore, normalize_entity
from repro.kernels.ref import graph_expand_ref

EMB = HashEmbedder()

PEOPLE = ["Caroline", "Dave", "Mel"]
TEXTS = [
    "I adopted a cat named Muffin.",
    "Muffin is allergic to peanuts.",
    "I work as a teacher.",
    "I work as a nurse.",
    "I went to Banff. I started aikido classes.",
    "My favorite color is teal.",
    "I live in Lisbon.",
    "I bought a camera.",
    "I am learning the cello.",
]


def _store(**kw):
    return MemoryStore(EMB, **kw)


def _fill(store, namespaces=("u1", "u2"), sessions=3, rng=None):
    rng = rng or np.random.default_rng(0)
    for ns in namespaces:
        for s in range(sessions):
            msgs = [Message(str(rng.choice(PEOPLE)), str(rng.choice(TEXTS)))
                    for _ in range(3)]
            store.ingest(ns, f"s{s}", msgs)
    return store


def _expand_both(store, queries, namespaces, hops_b, k=16, max_hops=2,
                 seed_k=8, decay=0.5, tw=None):
    """Run the device expansion AND the scalar oracle on identical inputs;
    returns ((ids, scores), (oracle_ids, oracle_scores))."""
    g = store.graph
    q_ns = np.asarray([store.tenant(ns).ns_id for ns in namespaces],
                      np.int32)
    if tw is None:
        tw = np.tile(np.asarray([[1.0, 0.9, 0.9]], np.float32),
                     (len(queries), 1))
    qv = np.asarray(EMB.embed_texts(list(queries)), np.float32)
    _, dense_ids = store.vindex.search_batch(qv, q_ns, k=8)
    _, sparse_ids = store.bm25.topk_batch_dev(list(queries), k=8,
                                              namespaces=list(q_ns))
    rankings = [np.asarray(dense_ids), np.asarray(sparse_ids)]
    ids, scores, _, _ = g.expand(rankings, q_ns,
                                 store.row_namespaces_device(), tw,
                                 np.asarray(hops_b, np.int32), k=k,
                                 max_hops=max_hops, seed_k=seed_k,
                                 decay=decay)
    row_labels = np.asarray(store.row_namespaces_device())
    es, ed, et, ew = g.edges()
    rs, ro = g.row_incidence()
    oids, oscores = graph_expand_ref(
        es, ed, et, ew, g.node_ns(), rs, ro, row_labels, rankings, q_ns,
        tw, np.asarray(hops_b, np.int32), hops=max_hops, k=k,
        seed_k=seed_k, decay=decay)
    return ((np.asarray(ids), np.asarray(scores, np.float32)),
            (oids, oscores))


def _assert_parity(store, queries, namespaces, hops_b, **kw):
    (ids, scores), (oids, oscores) = _expand_both(
        store, queries, namespaces, hops_b, **kw)
    np.testing.assert_array_equal(ids, oids)
    np.testing.assert_array_equal(scores, oscores)   # exact f32, not close


# -- satellite: Triple.key normalization --------------------------------------

def test_triple_key_normalizes_case_and_whitespace():
    assert normalize_entity("  Caroline\t Smith ") == "caroline smith"
    t1 = Triple("Caroline", "Works As", "teacher", timestamp=1.0)
    t2 = Triple("caroline ", " works  as", "nurse", timestamp=2.0)
    assert t1.key() == t2.key() == "caroline|works as"


def test_latest_for_key_on_mixed_case_duplicates():
    """Aliased subjects ("Caroline" vs "caroline") are ONE version chain:
    latest_for_key resolves across them and superseded_ids retires the
    older spelling — before the fix they silently split into two chains."""
    ts = TripleStore()
    a = ts.add(Triple("Caroline", "works as", "teacher", timestamp=1.0))
    ts.add(Triple("caroline", "Works as", "nurse", timestamp=2.0))
    latest = ts.latest_for_key("caroline|works as")
    assert latest is not None and latest.object == "nurse"
    assert ts.superseded_ids() == [a]
    assert len(ts.versions(a)) == 2


# -- graph construction -------------------------------------------------------

def test_ingest_builds_entity_temporal_causal_edges():
    store = _store()
    store.ingest("u1", "s1", [
        Message("Caroline", "I adopted a cat named Muffin."),
        Message("Caroline", "I work as a teacher."),
    ])
    store.ingest("u1", "s2", [Message("Caroline", "I work as a nurse.")])
    g = store.graph
    n = {t: i for i, t in enumerate(g._node_text)}
    es, ed, et, _ = g.edges()
    edges = set(zip(es.tolist(), ed.tolist(), et.tolist()))
    # entity: subject <-> object, both directions
    assert (n["caroline"], n["cat"], EDGE_ENTITY) in edges
    assert (n["cat"], n["caroline"], EDGE_ENTITY) in edges
    # temporal: consecutive triples' objects within one session
    assert (n["cat"], n["muffin"], EDGE_TEMPORAL) in edges \
        or (n["muffin"], n["teacher"], EDGE_TEMPORAL) in edges
    # causal: the "works as" version chain links teacher -> nurse
    assert (n["teacher"], n["nurse"], EDGE_CAUSAL) in edges
    assert (n["nurse"], n["teacher"], EDGE_CAUSAL) in edges
    # CSR offsets cover every edge exactly once
    offs = g.csr_offsets()
    assert offs[-1] == g.n_edges and len(offs) == g.n_nodes + 1


def test_interning_collapses_aliases_and_separates_namespaces():
    g = MemoryGraph()
    a = g.intern(0, "Caroline")
    assert g.intern(0, "  caroline ") == a
    assert g.intern(1, "Caroline") != a          # same text, other tenant
    assert g.node_ns().tolist() == [0, 1]


def test_row_alignment_drift_raises_store_invariant_error():
    store = _fill(_store(), sessions=1)
    store.graph._n_rows -= 1                     # simulate lane drift
    with pytest.raises(StoreInvariantError):
        store.ingest("u1", "sX", [Message("Mel", "I live in Lisbon.")])


def test_compact_map_size_mismatch_raises():
    g = MemoryGraph()
    g.append_row(0, -1, -1)
    with pytest.raises(GraphInvariantError):
        g.compact_rows(np.asarray([0, 1], np.int64))
    with pytest.raises(GraphInvariantError):
        g.append_row(5, -1, -1)                  # out-of-order row append


# -- expansion == oracle ------------------------------------------------------

def test_expansion_matches_oracle_basic():
    store = _fill(_store())
    _assert_parity(store, ["allergic", "camera", "nurse"],
                   ["u1", "u2", "u1"], [2, 1, 2])


def test_expansion_matches_oracle_after_evict_and_compact():
    store = _fill(_store())
    store.evict_superseded("u1")
    _assert_parity(store, ["nurse", "Banff"], ["u1", "u2"], [2, 2])
    store.evict_namespace("u2")
    _assert_parity(store, ["nurse", "Banff"], ["u1", "u2"], [2, 2])
    store.compact()
    _assert_parity(store, ["nurse", "Banff"], ["u1", "u1"], [3, 1],
                   max_hops=4)


def test_expansion_matches_oracle_after_restore(tmp_path):
    store = _fill(_store())
    p = str(tmp_path / "snap.ckpt")
    store.snapshot(p)
    restored = MemoryStore.restore(p, EMB)
    a = _expand_both(store, ["allergic"], ["u1"], [2])
    b = _expand_both(restored, ["allergic"], ["u1"], [2])
    np.testing.assert_array_equal(a[0][0], b[0][0])     # device == device
    np.testing.assert_array_equal(a[0][1], b[0][1])     # bit-identical
    _assert_parity(restored, ["allergic"], ["u1"], [2])
    # and the restored graph keeps growing the same version chains
    restored.ingest("u1", "s9", [Message("Caroline", "I work as a chef.")])
    store.ingest("u1", "s9", [Message("Caroline", "I work as a chef.")])
    assert restored.graph.edge_type_counts() == \
        store.graph.edge_type_counts()


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_expansion_matches_oracle_interleaved(seed):
    """add / evict / compact / snapshot-restore interleaved, parity checked
    after every step (the deterministic core of the property test below)."""
    rng = np.random.default_rng(seed)
    store = _fill(_store(), sessions=2, rng=rng)

    def check():
        qs = [str(rng.choice(TEXTS)).split()[-1] for _ in range(3)]
        nss = [str(rng.choice(["u1", "u2", "ghost"])) for _ in range(3)]
        hops = rng.integers(1, 4, size=3).tolist()
        _assert_parity(store, qs, nss, hops, max_hops=4,
                       seed_k=int(rng.integers(1, 9)))

    check()
    store.ingest("u1", "sA", [Message("Dave", str(rng.choice(TEXTS)))])
    check()
    store.evict_superseded("u1")
    check()
    store.compact()
    check()
    store.ingest("u2", "sB", [Message("Mel", str(rng.choice(TEXTS)))
                              for _ in range(2)])
    check()


try:
    import hypothesis  # noqa: F401
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                               # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    _OPS = st.lists(
        st.one_of(
            st.tuples(st.just("ingest"), st.integers(0, 1),
                      st.lists(st.integers(0, len(TEXTS) - 1), min_size=1,
                               max_size=3)),
            st.tuples(st.just("evict_superseded"), st.integers(0, 1),
                      st.just([])),
            st.tuples(st.just("evict_ns"), st.integers(0, 1), st.just([])),
            st.tuples(st.just("compact"), st.just(0), st.just([])),
            st.tuples(st.just("restore"), st.just(0), st.just([])),
        ), min_size=1, max_size=6)

    @given(_OPS, st.integers(1, 3), st.integers(1, 8))
    @settings(max_examples=12, deadline=None)
    def test_property_kernel_equals_bfs_oracle(ops, hops, seed_k):
        """Hypothesis: under ANY interleaving of ingest / evict / compact /
        snapshot-restore, the batched k-hop kernel returns exactly the
        scalar BFS oracle's ids, order and float32 scores."""
        import tempfile
        store = _store()
        nss = ("u1", "u2")
        si = 0
        for op, tenant, texts in ops:
            ns = nss[tenant]
            if op == "ingest":
                msgs = [Message(PEOPLE[i % len(PEOPLE)], TEXTS[i])
                        for i in texts]
                store.ingest(ns, f"s{si}", msgs)
                si += 1
            elif op == "evict_superseded":
                store.evict_superseded(ns)
            elif op == "evict_ns":
                store.evict_namespace(ns)
            elif op == "compact":
                store.compact()
            elif op == "restore":
                with tempfile.TemporaryDirectory() as d:
                    p = f"{d}/snap.ckpt"
                    store.snapshot(p)
                    store = MemoryStore.restore(p, EMB)
        _assert_parity(store, ["allergic teacher", "Banff camera"],
                       ["u1", "u2"], [hops, max(1, hops - 1)],
                       max_hops=4, seed_k=seed_k)


# -- steady state: zero recompiles, zero lane re-uploads ----------------------

def test_no_recompile_no_upload_while_edges_grow_within_bucket(monkeypatch):
    """The device-residency contract: while the edge lanes grow WITHIN a
    pow2 capacity bucket, steady-state expansions reuse one executable
    (zero compiles) and never re-upload a capacity-sized lane (the only
    jnp.asarray calls in the graph module are the pow2-padded deltas)."""
    g = MemoryGraph()
    for i in range(20):
        g.intern(0, f"ent{i}")
    for r in range(24):
        g.append_row(r, r % 20, (r + 1) % 20)
    for i in range(0, 16, 2):
        g.link_nodes(i, i + 1, EDGE_ENTITY)
    row_labels = jnp.asarray(np.zeros(64, np.int32))
    rankings = [np.arange(16, dtype=np.int32)[None, :].repeat(2, axis=0)]
    q_ns = np.zeros(2, np.int32)
    tw = np.ones((2, 3), np.float32)
    hops_b = np.asarray([2, 2], np.int32)

    def run():
        ids, _, _, _ = g.expand(rankings, q_ns, row_labels, tw, hops_b,
                                k=16, max_hops=2, seed_k=8, decay=0.5)
        return np.asarray(ids)

    run()                                 # materialize + compile
    g.link_nodes(16, 17, EDGE_ENTITY)     # warm the width-2 edge append
    run()
    assert g._edge_src.shape[0] == 64     # still in the first bucket

    uploads = []
    real_asarray = graph_mod.jnp.asarray

    def spy_asarray(x, *a, **kw):
        if getattr(x, "nbytes", 0) >= 64 * 4:
            uploads.append(np.shape(x))
        return real_asarray(x, *a, **kw)

    monkeypatch.setattr(graph_mod.jnp, "asarray", spy_asarray)
    with count_compiles() as cc:
        for i in range(8):
            g.link_nodes(17 + (i % 2), i % 16, EDGE_TEMPORAL)
            run()
    assert cc.count == 0, f"recompiled {cc.count}x: {cc.msgs[:3]}"
    assert uploads == [], f"lane-sized host->device transfers: {uploads}"
    assert g.n_edges <= 64                # never left the bucket


def test_growth_across_bucket_recompiles_then_restabilizes():
    g = MemoryGraph()
    for i in range(8):
        g.intern(0, f"e{i}")
    g.append_row(0, 0, 1)
    row_labels = jnp.asarray(np.zeros(64, np.int32))
    args = ([np.asarray([[0]], np.int32)], np.zeros(1, np.int32),
            row_labels, np.ones((1, 3), np.float32),
            np.asarray([2], np.int32))

    def run():
        return np.asarray(g.expand(*args, k=8, max_hops=2, seed_k=4,
                                   decay=0.5)[0])

    run()
    for i in range(40):                   # blow through the 64-edge bucket
        g.link_nodes(i % 8, (i + 3) % 8, i % 3)
    assert g.n_edges > 64 or g._edge_src.shape[0] == 64
    run()                                 # recompile at the new capacity
    with count_compiles() as cc:
        g.link_nodes(0, 5, EDGE_CAUSAL)
        run()
    assert cc.count == 0


# -- namespace isolation ------------------------------------------------------

def test_expansion_never_crosses_namespaces():
    store = _store()
    for ns in ("u1", "u2"):
        store.ingest(ns, "s0", [
            Message("Caroline", "I adopted a cat named Muffin."),
            Message("Caroline", "Muffin is allergic to peanuts."),
        ])
    t1, t2 = store.tenant("u1"), store.tenant("u2")
    rows_u2 = set(t2.rows)
    # seed_k=1 so only the best seed row's nodes seed the walk and the
    # rest of the chain must be DISCOVERED (seed nodes never score rows)
    (ids, scores), _ = _expand_both(
        store, ["Muffin allergic"], ["u1"], [3], max_hops=4, seed_k=1)
    hit = set(int(r) for r in ids[0] if r >= 0)
    assert hit and not (hit & rows_u2)
    assert all(int(store.vindex.row_namespaces()[r]) == t1.ns_id
               for r in hit)
    # same surface through the service: u1's graph-expanded retrieval only
    # ever renders u1's triples
    svc = MemoryService(store=store)
    ctx = svc.retrieve("u1", "what is Muffin allergic to",
                       stages=("dense", "sparse", "graph", "budget"))
    assert all(tr.conversation_id == "u1" for tr in ctx.triples)


# -- durability ---------------------------------------------------------------

def test_graph_survives_snapshot_restore_bit_identical(tmp_path):
    store = _fill(_store())
    store.link("u1", "Muffin", "vet visits", "causal", weight=0.8)
    p = str(tmp_path / "snap.ckpt")
    store.snapshot(p)
    r = MemoryStore.restore(p, EMB)
    g1, g2 = store.graph, r.graph
    assert g1._node_text == g2._node_text
    np.testing.assert_array_equal(g1.node_ns(), g2.node_ns())
    for x, y in zip(g1.edges(), g2.edges()):
        np.testing.assert_array_equal(x, y)
    for x, y in zip(g1.row_incidence(), g2.row_incidence()):
        np.testing.assert_array_equal(x, y)
    assert g1._tail == g2._tail and g1._edge_idx == g2._edge_idx


def test_restore_refuses_misaligned_graph_lanes(tmp_path):
    store = _fill(_store(), sessions=1)
    p = str(tmp_path / "snap.ckpt")
    store.snapshot(p)
    arrays = ckpt_io.load_raw(p)
    arrays["graph_row_sub"] = arrays["graph_row_sub"][:-1]
    arrays["graph_row_obj"] = arrays["graph_row_obj"][:-1]
    p2 = str(tmp_path / "tampered.ckpt")
    ckpt_io.save(p2, dict(arrays))
    with pytest.raises(StoreInvariantError):
        MemoryStore.restore(p2, EMB)


def test_graph_edge_wal_record_replays_bit_identical(tmp_path):
    """link() journals BEFORE applying; replaying the captured records into
    a fresh store rebuilds the exact same graph lanes."""
    records = []
    store = _store()
    store.wal_sink = records.append
    _fill(store, sessions=2)
    store.link("u1", "Caroline", "marathon training", "entity")
    store.link("u1", "marathon training", "knee injury", "causal",
               weight=0.5)
    assert any(r["op"] == "graph_edge" for r in records)
    replayed = _store()
    for r in records:
        replayed.apply_wal(r)
    g1, g2 = store.graph, replayed.graph
    assert g1._node_text == g2._node_text
    for x, y in zip(g1.edges(), g2.edges()):
        np.testing.assert_array_equal(x, y)
    for x, y in zip(g1.row_incidence(), g2.row_incidence()):
        np.testing.assert_array_equal(x, y)
    _assert_parity(replayed, ["marathon"], ["u1"], [2])


def test_link_validates_edge_type():
    store = _store()
    with pytest.raises(ValueError):
        store.link("u1", "a", "b", "telepathic")


# -- the service stage --------------------------------------------------------

def test_graph_stage_mixed_batch_matches_solo_execution():
    """A batch where only SOME requests run the graph stage: every request
    answers exactly like the same request executed alone (the expanded
    ranking is masked to -1 for the others)."""
    svc = MemoryService(store=_fill(_store()))
    reqs = [
        RetrieveRequest("u1", "allergic", stages=("dense", "sparse",
                                                  "graph"), hops=2),
        RetrieveRequest("u2", "camera"),
        RetrieveRequest("u1", "nurse",
                        stages=("dense", "sparse", "graph"), hops=1,
                        edge_weights=(1.0, 0.5, 2.0), graph_weight=1.5),
    ]
    plan = RetrievalPlan.raw()
    batched = svc.execute(reqs, plan=plan)
    for req, got in zip(reqs, batched):
        solo = svc.execute([req], plan=plan)[0]
        assert got.row_ids == solo.row_ids
        assert got.scores == solo.scores


def test_graph_stage_changes_ranking_and_surfaces_chain():
    """The acceptance shape: a 2-hop chain fact (pet -> name -> allergen)
    that flat hybrid retrieval misses is surfaced by the graph plan."""
    svc = MemoryService(EMB, top_k=5)
    svc.record("u1", "s0", [
        Message("Caroline", "I adopted a cat named Muffin."),
        Message("Caroline", "My favorite color is teal."),
    ])
    svc.record("u1", "s1", [
        Message("Caroline", "Muffin is allergic to peanuts."),
    ])
    for i in range(16):   # noise rows so flat top-k has competition and
        # the seed window doesn't blanket the whole (tiny) graph
        svc.record("u1", f"n{i}", [Message("Dave", TEXTS[i % len(TEXTS)])])
    q = "What food can Caroline's cat never eat?"
    flat = svc.execute([RetrieveRequest("u1", q)],
                       plan=RetrievalPlan.raw())[0]
    # graph_seed_k=2: the chain HEAD ("cat is named muffin") seeds the
    # walk but the answer row does not — it must be discovered via the
    # muffin -> peanuts edge (seeded rows never score, so a wide seed
    # window over a tiny corpus would leave nothing to discover)
    graph = svc.execute([RetrieveRequest("u1", q, hops=2)],
                        plan=RetrievalPlan.graph_expanded(
                            budget=False, graph_seed_k=2))[0]
    t = svc.store.get("u1")

    def texts(raw):
        return [t.triples.get(tid).text() for tid in raw.triple_ids]
    target = "Muffin is allergic to peanuts"
    assert any(target in x for x in texts(graph))
    assert texts(graph) != texts(flat)


def test_graph_plan_validation():
    with pytest.raises(ValueError):
        RetrievalPlan(stages=("graph", "fuse"))      # no seed stage
    with pytest.raises(ValueError):
        RetrieveRequest("u1", "q", hops=0)
    with pytest.raises(ValueError):
        RetrieveRequest("u1", "q", edge_weights=(1.0, 1.0))
    with pytest.raises(ValueError):
        RetrievalPlan(graph_decay=0.0)
    assert RetrievalPlan.graph_expanded().wants_graph
    assert not RetrievalPlan().wants_graph           # opt-in, not default


# -- telemetry ----------------------------------------------------------------

def test_graph_span_and_metrics_in_scrape():
    """plan.graph span attrs (frontier sizes, edges touched, launches) in
    the trace tree, memori_graph_* gauges + the expansion latency histogram
    in the Prometheus scrape — strict exposition-format checks."""
    from repro.obs.telemetry import Telemetry, set_telemetry, walk_spans
    from repro.serving.frontend import flatten_metrics
    tel = Telemetry()
    set_telemetry(tel)
    try:
        svc = MemoryService(store=_fill(_store()))
        tr = tel.start_trace(op="retrieve")
        with tel.activate([tr]):
            svc.execute([RetrieveRequest("u1", "allergic", hops=2)],
                        plan=RetrievalPlan.graph_expanded(budget=False))
        tel.finish_trace(tr)
        spans = {s["name"]: s for s in walk_spans(tr.to_dict()["root"])}
        g = spans["plan.graph"]["attrs"]
        assert g["launches"] == 1
        assert len(g["frontier_sizes"]) == g["hops_compiled"]
        assert len(g["edges_touched"]) == g["hops_compiled"]
        assert g["edges"] == svc.store.graph.n_edges
        # gauges ride the stats() flattening used by /v1/metrics
        names = {n for n, _ in flatten_metrics(svc.stats())}
        for want in ("memori_graph_nodes", "memori_graph_edges",
                     "memori_graph_edges_causal",
                     "memori_graph_rows_with_incidence"):
            assert want in names, f"missing gauge {want}"
        # histogram + counters in the exposition text
        text = tel.render()
        assert "# TYPE memori_graph_expand_latency_seconds histogram" in text
        assert "memori_graph_expand_latency_seconds_bucket" in text
        count = [ln for ln in text.splitlines()
                 if ln.startswith("memori_graph_expand_latency_seconds_count")]
        assert count and float(count[0].split()[-1]) >= 1
        assert "memori_graph_expansions_total" in text
    finally:
        set_telemetry(Telemetry())
